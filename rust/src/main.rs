//! `ssm-rdu` — leader entrypoint and CLI.
//!
//! Subcommands:
//!   spec                         print Table I (RDU architectural spec)
//!   table2                       print Table II (platform specs)
//!   table4                       print Table IV (area/power overheads)
//!   fig7 | fig8 | fig11 | fig12  regenerate a paper figure (DFModel)
//!   all                          every table and figure in order
//!   simulate [--lanes N --stages M]
//!                                run the cycle-level PCU simulator demo
//!   dot --model <attention|hyena|mamba> [--seq-len L]
//!                                dump a workload dataflow graph (graphviz)
//!   serve [--artifacts DIR --requests N --workers W --max-batch B
//!          --max-wait-ms MS]
//!                                serve one-shot batched requests through
//!                                the PJRT runtime (the E2E driver's engine)
//!   serve --continuous [--sessions N --decode-steps K --workers W
//!                       --max-batch B --cache-mb M --layers L --d-state S
//!                       --state-d-model D --fft-points P
//!                       --session-timeout-ms MS]
//!                                continuous-batching session serving over
//!                                the MockExecutor: N live sessions decode
//!                                K tokens each through the SessionScheduler
//!                                + StateCache (LRU, byte budget, spill
//!                                accounting). Default budget is half the
//!                                total state footprint so eviction is
//!                                exercised; override with --cache-mb.

use ssm_rdu::arch::{PcuGeometry, RduConfig};
use ssm_rdu::coordinator::{
    BatchPolicy, ContinuousConfig, Coordinator, CoordinatorConfig, Executor, MockExecutor,
    PjrtExecutor,
};
use ssm_rdu::figures;
use ssm_rdu::pcusim::{self, Pcu};
use ssm_rdu::runtime::{default_artifacts_dir, ModelKind};
use ssm_rdu::session::{SchedulerConfig, StateShape};
use ssm_rdu::util::cli::Args;
use ssm_rdu::util::{fmt_time, C64, XorShift};
use ssm_rdu::workloads::{
    attention_decoder, hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant,
};
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("all");
    let code = match cmd {
        "spec" => {
            figures::table1().print();
            0
        }
        "table2" => {
            figures::platforms::table2().print();
            0
        }
        "table4" => {
            figures::table4().print();
            0
        }
        "fig7" => {
            let f = figures::hyena::fig7_at(&seq_lens(&args));
            f.table().print();
            f.speedup_report().print();
            0
        }
        "fig8" => {
            let f = figures::platforms::fig8_at(&seq_lens(&args));
            f.table().print();
            f.speedup_report().print();
            0
        }
        "fig11" => {
            let f = figures::mamba::fig11_at(&seq_lens(&args));
            f.table().print();
            f.speedup_report().print();
            0
        }
        "fig12" => {
            let f = figures::mamba::fig12_at(*seq_lens(&args).last().unwrap());
            f.table().print();
            f.speedup_report().print();
            0
        }
        "all" => {
            figures::table1().print();
            figures::platforms::table2().print();
            let f7 = figures::fig7();
            f7.table().print();
            f7.speedup_report().print();
            let f8 = figures::fig8();
            f8.table().print();
            f8.speedup_report().print();
            let f11 = figures::fig11();
            f11.table().print();
            f11.speedup_report().print();
            let f12 = figures::fig12();
            f12.table().print();
            f12.speedup_report().print();
            figures::table4().print();
            0
        }
        "simulate" => simulate(&args),
        "dot" => dot(&args),
        "serve" => serve(&args),
        other => {
            eprintln!("unknown subcommand `{other}`; see `rust/src/main.rs` docs for usage");
            2
        }
    };
    std::process::exit(code);
}

fn seq_lens(args: &Args) -> Vec<usize> {
    args.usize_list_or("seq-lens", &figures::PAPER_SEQ_LENS)
}

/// Demonstrate the PCU simulator: FFT and scan programs on baseline vs
/// extended PCUs, printing regime, throughput and utilization.
fn simulate(args: &Args) -> i32 {
    let lanes = args.usize_or("lanes", 32);
    let stages = args.usize_or("stages", 12);
    let geom = PcuGeometry::new(lanes, stages);
    let mut rng = XorShift::new(42);
    let batch: Vec<Vec<C64>> = (0..2048)
        .map(|_| (0..lanes).map(|_| C64::real(rng.uniform(-1.0, 1.0))).collect())
        .collect();

    println!("PCU simulator: {geom} geometry, {} input vectors", batch.len());
    let prog = pcusim::fft_program(lanes);
    for (name, pcu) in [("baseline", Pcu::baseline(geom)), ("fft-mode", Pcu::fft_mode(geom))] {
        let (_, stats) = pcu.run(&prog, &batch);
        println!(
            "  {name:9} fft{lanes}:     {} regime, II={:.2} cyc/vec, FU util={:.1}%",
            if stats.spatial { "spatial   " } else { "serialized" },
            stats.initiation_interval(),
            stats.utilization() * 100.0
        );
    }
    let scan = pcusim::hs_scan_program(lanes);
    for (name, pcu) in [("baseline", Pcu::baseline(geom)), ("hs-mode", Pcu::hs_scan_mode(geom))] {
        let (_, stats) = pcu.run(&scan, &batch);
        println!(
            "  {name:9} hs-scan{lanes}: {} regime, II={:.2} cyc/vec, FU util={:.1}%",
            if stats.spatial { "spatial   " } else { "serialized" },
            stats.initiation_interval(),
            stats.utilization() * 100.0
        );
    }
    0
}

/// Dump a workload graph as graphviz dot.
fn dot(args: &Args) -> i32 {
    let l = args.usize_or("seq-len", 1 << 20);
    let dc = DecoderConfig::paper(l);
    let model = args.get_or("model", "hyena");
    let g = match model.as_str() {
        "attention" => attention_decoder(&dc),
        "hyena" => hyena_decoder(&dc, ssm_rdu::fft::BaileyVariant::Vector),
        "mamba" => mamba_decoder(&dc, ScanVariant::Parallel),
        other => {
            eprintln!("unknown model `{other}`");
            return 2;
        }
    };
    println!("{}", g.to_dot());
    0
}

/// Serve synthetic batched requests through the PJRT runtime, or — with
/// `--continuous` — live decode sessions through the continuous-batching
/// session subsystem (MockExecutor; per-token kernels are not AOT-lowered).
fn serve(args: &Args) -> i32 {
    if args.flag("continuous") {
        return serve_continuous(args);
    }
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let n = args.usize_or("requests", 32);
    let workers = args.usize_or("workers", 1);
    let max_batch = args.usize_or("max-batch", 4);
    let wait_ms = args.usize_or("max-wait-ms", 5);

    println!("loading artifacts from {} …", dir.display());
    // Shape probe (cheap manifest read) before spinning up workers.
    let manifest = match ssm_rdu::runtime::Manifest::load(dir.join("manifest.json")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("cannot read manifest: {e:#}\nhint: run `make artifacts` first");
            return 1;
        }
    };
    let elems = manifest.seq_len * manifest.d_model;
    let models: Vec<ModelKind> = manifest.models.keys().copied().collect();

    let dir2 = dir.clone();
    let coord = match Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms as u64) },
            workers,
            ..Default::default()
        },
        Box::new(move || {
            let exec = PjrtExecutor::load(&dir2)?;
            Ok(Box::new(exec) as Box<dyn Executor>)
        }),
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start coordinator: {e:#}");
            return 1;
        }
    };

    println!("serving {n} requests round-robin over {models:?} …");
    let mut rng = XorShift::new(7);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let model = models[i % models.len()];
            let input: Vec<f32> = (0..elems).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            coord.submit(model, input).expect("submit")
        })
        .collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "done: {ok}/{n} ok in {} ({:.1} req/s)  |  {}",
        fmt_time(wall.as_secs_f64()),
        ok as f64 / wall.as_secs_f64(),
        coord.metrics.summary()
    );
    coord.shutdown();

    // Tie the serving stack back to the paper's performance model: print the
    // modeled-RDU latency for the same decoder shapes.
    let dc = DecoderConfig::paper(manifest.seq_len);
    for (name, g, cfg) in [
        ("hyena", hyena_decoder(&dc, ssm_rdu::fft::BaileyVariant::Vector), RduConfig::fft_mode()),
        ("mamba", mamba_decoder(&dc, ScanVariant::Parallel), RduConfig::hs_scan_mode()),
    ] {
        if let Ok(est) = ssm_rdu::dfmodel::estimate(&g, &cfg) {
            println!(
                "modeled {} latency for {name} @ L={}: {}",
                cfg.name(),
                manifest.seq_len,
                fmt_time(est.total_seconds)
            );
        }
    }
    0
}

/// `serve --continuous`: N live sessions stream K tokens each through the
/// session subsystem (scheduler + state cache) over the worker pool.
fn serve_continuous(args: &Args) -> i32 {
    let sessions = args.usize_or("sessions", 96);
    let decode_steps = args.usize_or("decode-steps", 32);
    let workers = args.usize_or("workers", 2);
    let max_batch = args.usize_or("max-batch", 16);
    let layers = args.usize_or("layers", 8);
    let d_state = args.usize_or("d-state", 16);
    let d_model = args.usize_or("state-d-model", 64);
    let fft_points = args.usize_or("fft-points", 256);
    let timeout_ms = args.usize_or("session-timeout-ms", 30_000);

    let mamba_shape = StateShape::mamba(layers, d_state, d_model);
    let hyena_shape = StateShape::hyena(layers, d_model, fft_points);
    let model_of = |i: usize| if i % 2 == 0 { ModelKind::Mamba } else { ModelKind::Hyena };
    let footprint: usize = (0..sessions)
        .map(|i| {
            if model_of(i) == ModelKind::Mamba {
                mamba_shape.bytes()
            } else {
                hyena_shape.bytes()
            }
        })
        .sum();
    // Default budget: half the footprint, so the demo exercises eviction;
    // always at least one state so decode can make progress.
    let budget_bytes = match args.get("cache-mb") {
        Some(_) => args.usize_or("cache-mb", 8) * (1 << 20),
        None => (footprint / 2).max(mamba_shape.bytes().max(hyena_shape.bytes())),
    };
    println!(
        "continuous serving: {sessions} sessions × {decode_steps} tokens, {workers} workers, \
         batch {max_batch}"
    );
    println!(
        "state footprint {:.1} KiB vs cache budget {:.1} KiB ({})",
        footprint as f64 / 1024.0,
        budget_bytes as f64 / 1024.0,
        if budget_bytes < footprint { "expect spills" } else { "fully resident" }
    );

    let cc = ContinuousConfig {
        sched: SchedulerConfig {
            max_batch,
            session_timeout: Duration::from_millis(timeout_ms as u64),
        },
        budget_bytes,
        mamba_shape,
        hyena_shape,
    };
    let coord = match Coordinator::start(
        CoordinatorConfig {
            workers,
            max_inflight: sessions.max(1) * 2,
            continuous: Some(cc),
            ..Default::default()
        },
        Box::new(move || Ok(Box::new(MockExecutor::new(1, d_model)) as Box<dyn Executor>)),
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start coordinator: {e:#}");
            return 1;
        }
    };

    let mut rng = XorShift::new(11);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..sessions)
        .map(|i| {
            let prompt: Vec<f32> =
                (0..d_model * 4).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            coord.submit_session(model_of(i), prompt, decode_steps).expect("submit_session")
        })
        .collect();
    let mut tokens = 0u64;
    let mut complete = 0usize;
    for rx in rxs {
        let mut got = 0usize;
        while rx.recv().is_ok() {
            got += 1;
            tokens += 1;
        }
        if got == decode_steps {
            complete += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "done: {complete}/{sessions} sessions complete, {tokens} tokens in {} ({:.0} tok/s)",
        fmt_time(wall.as_secs_f64()),
        tokens as f64 / wall.as_secs_f64()
    );
    println!("metrics: {}", coord.metrics.summary());
    if let Some(cs) = coord.cache_stats() {
        println!(
            "cache: hits={} misses={} evictions={} restores={} spilled={:.1} KiB \
             restored={:.1} KiB peak_resident={:.1} KiB hit_rate={:.1}% spill_time={}",
            cs.hits,
            cs.misses,
            cs.evictions,
            cs.restores,
            cs.spilled_bytes as f64 / 1024.0,
            cs.restored_bytes as f64 / 1024.0,
            cs.peak_resident_bytes as f64 / 1024.0,
            cs.hit_rate() * 100.0,
            fmt_time(cs.spill_seconds),
        );
    }
    if let Some(ss) = coord.scheduler_stats() {
        println!(
            "scheduler: admitted={} retired={} expired={} failed={} prefill_steps={} \
             decode_steps={} batches={}",
            ss.admitted, ss.retired, ss.expired, ss.failed, ss.prefill_steps, ss.decode_steps,
            ss.batches,
        );
    }
    // Tie back to the paper's performance model: the modeled per-token
    // decode-step latency for these shapes on the extended RDU.
    for (model, shape, cfg) in [
        (ModelKind::Mamba, &mamba_shape, RduConfig::hs_scan_mode()),
        (ModelKind::Hyena, &hyena_shape, RduConfig::fft_mode()),
    ] {
        let dc = DecoderConfig {
            seq_len: 1,
            d_model: shape.d_model,
            mlp_mult: 4,
            dtype_bytes: 2.0,
            fft_tile: 32,
            state_dim: shape.d_state.max(1),
            expand: 1,
        };
        let cost = ssm_rdu::dfmodel::decode_step(model, &dc, shape.layers, &cfg);
        println!(
            "modeled {model} decode step on {}: {} ({:.0} cycles, state {:.1} KiB/step)",
            cfg.name(),
            fmt_time(cost.seconds),
            cost.cycles,
            cost.state_bytes / 1024.0,
        );
    }
    coord.shutdown();
    if complete == sessions {
        0
    } else {
        1
    }
}
