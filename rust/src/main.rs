//! `ssm-rdu` — leader entrypoint and CLI.
//!
//! Usage: `ssm-rdu <subcommand> [--options]`. The full CLI reference with
//! examples lives in `README.md`; this block is the canonical summary and
//! must stay in sync with the README and the `other =>` usage error below.
//!
//! Subcommands:
//!   spec                         print Table I (RDU architectural spec)
//!   table2                       print Table II (platform specs)
//!   table4                       print Table IV (area/power overheads)
//!   fig7 | fig8 | fig11 | fig12  regenerate a paper figure (DFModel);
//!                                --seq-lens L1,L2,… overrides the sweep
//!   all                          every table and figure in order
//!   simulate [--lanes N --stages M] [--chips P --seq-len L] [--fuse]
//!            [--workload W1,W2,…] [--trace FILE --metrics FILE]
//!                                run the cycle-level PCU simulator demo and
//!                                print each selected workload's golden-model
//!                                self-check; with --fuse also run the fused
//!                                FFT→filter→iFFT conv pipeline and the
//!                                fused scan→gate (bit-identical to their
//!                                unfused launches) and print the fused-vs-
//!                                unfused DFModel latency table; with
//!                                --chips > 1 also verify the sharded
//!                                scan/FFT dataflows numerically and print
//!                                the strong-scaling sweep (speedup and
//!                                communication share per chip count) for
//!                                the selected workloads
//!   debug [--program P --lanes N --stages M --vectors V --seed K]
//!         [--break-stage LABEL|IDX --break-cycle C --step K]
//!         [--dump --json FILE --expect-noc --serialized --interactive]
//!                                single-step a PCU program in the pcusim
//!                                debugger: run to a stage/cycle breakpoint,
//!                                dump pipeline registers and NoC route
//!                                traffic, then resume and verify the
//!                                interrupted run reproduces the engine's
//!                                outputs and ExecStats exactly. --program
//!                                names any canonical program (fused_conv,
//!                                fft, dif_fft, idit_fft, freq_filter,
//!                                hs_scan, b_scan, reduction, twiddle);
//!                                --serialized forces the baseline-PCU
//!                                serialized regime; --interactive opens a
//!                                stdin REPL (s/c/b/r/dump/stats/q)
//!   sweep [--seq-len L] [--pcus N1,N2,…] [--stages S1,S2,…] [--fuse]
//!         [--workload W1,W2,…]
//!                                design-space ablations (PCU count, DRAM
//!                                technology, pipeline depth) over the
//!                                selected workloads (default: every
//!                                registered SSM — hyena, mamba, ssd, s4);
//!                                with --fuse also print the fusion-gain
//!                                table
//!   dot --model <name> [--seq-len L]
//!                                dump a workload dataflow graph (graphviz);
//!                                any registered workload name is valid
//!                                (attention, hyena, mamba, ssd, s4)
//!   serve [--artifacts DIR --requests N --workers W --max-batch B
//!          --max-wait-ms MS --chips P --fuse --workload W1,W2,…]
//!         [--trace FILE --metrics FILE]
//!                                serve one-shot batched requests through
//!                                the PJRT runtime (the E2E driver's
//!                                engine); the closing model report prices
//!                                the selected workloads, and with
//!                                --chips > 1 also the sequence-sharded
//!                                multi-chip deployment
//!   serve --continuous [--sessions N --decode-steps K --workers W
//!                       --max-batch B --cache-mb M --layers L --d-state S
//!                       --state-d-model D --fft-points P --chips P
//!                       --session-timeout-ms MS --fuse]
//!                      [--trace FILE --metrics FILE]
//!                                continuous-batching session serving over
//!                                the MockExecutor: N live sessions decode
//!                                K tokens each through the SessionScheduler
//!                                + StateCache (LRU, byte budget, spill
//!                                accounting). Sessions are striped across
//!                                P chips, each chip owning its own state
//!                                cache sized to its share of --cache-mb.
//!                                Default budget is half the total state
//!                                footprint so eviction is exercised.
//!   fleet [--nodes N --chips C --sessions S --loadgen poisson,bursty,…
//!          --rate R --policy P --slo-us U --network fabric|pcie5
//!          --cache-mb M --drain NODE@FRAC,… --fail NODE@FRAC,…
//!          --no-checkpoint --seed K] [--trace FILE --metrics FILE]
//!                                multi-node serving tier: a placement
//!                                router (round-robin | least-loaded |
//!                                affine) over N simulated nodes of C chips
//!                                each, driven by trace-generated arrivals
//!                                (any comma list of poisson, bursty,
//!                                diurnal) in modeled time. Prints the SLO
//!                                report (p50/p99/p999 token latency,
//!                                goodput vs throughput) and a per-node
//!                                table. --rate 0 (default) calibrates the
//!                                offered load to 1.2x one node's measured
//!                                capacity; --slo-us 0 (default) sets the
//!                                SLO to the single-node overload p50.
//!                                --drain/--fail schedule node drains and
//!                                fail-stops at FRAC (0..1) of the
//!                                undisturbed run's duration; with
//!                                checkpointing on (default) both are
//!                                lossless and the exit code enforces it.
//!
//! Observability (`simulate` and both `serve` forms): `--trace FILE` records
//! the run as Chrome trace-event JSON — load it at <https://ui.perfetto.dev>
//! for the host flame view (coordinator, scheduler waves, worker pool,
//! per-chip spill/restore and exchange tracks) plus, under `simulate`, the
//! pcusim per-cycle stage-occupancy timeline. `--metrics FILE` writes the
//! structured counter registry and tail-latency quantiles as JSON. Tracing
//! is off unless `--trace` is passed and costs ~one atomic load per site
//! when off (CI gates this at ≤1%; see `rust/benches/observe.rs`).

use ssm_rdu::arch::{InterchipLink, PcuGeometry, RduConfig};
use ssm_rdu::coordinator::{
    BatchPolicy, ContinuousConfig, Coordinator, CoordinatorConfig, Executor, MockExecutor,
    PjrtExecutor,
};
use ssm_rdu::figures;
use ssm_rdu::pcusim::{self, Pcu};
use ssm_rdu::runtime::{default_artifacts_dir, ModelKind};
use ssm_rdu::session::{SchedulerConfig, StateShape};
use ssm_rdu::shard;
use ssm_rdu::util::cli::Args;
use ssm_rdu::util::{fmt_time, max_abs_diff, C64, XorShift};
use ssm_rdu::workloads::{lookup, registry_names, ssm_workloads, DecoderConfig, Workload};
use std::time::Duration;

/// Resolve `--workload name1,name2,…` against the registry (default: every
/// registered SSM workload). Unknown names exit with the valid list — the
/// usage error the registry exists to keep honest.
fn selected_workloads(args: &Args) -> Result<Vec<&'static dyn Workload>, i32> {
    match args.get("workload") {
        None => Ok(ssm_workloads()),
        Some(list) => list
            .split(',')
            .map(|raw| {
                let name = raw.trim();
                lookup(name).ok_or_else(|| {
                    eprintln!(
                        "unknown workload `{name}`; registered workloads: {}",
                        registry_names().join(", ")
                    );
                    2
                })
            })
            .collect(),
    }
}

/// Turn the trace recorder on when `--trace FILE` was passed. Must run
/// before the instrumented work; off (the default) every span/instant site
/// is a single relaxed atomic load.
fn observability_begin(args: &Args) {
    if args.get("trace").is_some() {
        ssm_rdu::telemetry::enable();
    }
}

/// Flush `--trace`/`--metrics` outputs if requested: stop recording, drain
/// the thread-local buffers, append `extra_events` (e.g. the pcusim
/// timeline), and write the Chrome trace JSON and the counter/quantile
/// snapshot. Returns 1 if an output file could not be written, else 0.
fn write_observability(
    args: &Args,
    extra_events: Vec<ssm_rdu::telemetry::TraceEvent>,
    extra_metrics: &[(String, f64)],
) -> i32 {
    let mut code = 0;
    if let Some(path) = args.get("trace") {
        ssm_rdu::telemetry::disable();
        let mut events = ssm_rdu::telemetry::drain();
        events.extend(extra_events);
        match ssm_rdu::telemetry::write_trace(std::path::Path::new(path), &events) {
            Ok(()) => println!(
                "wrote {} trace events to {path} (load in Perfetto: https://ui.perfetto.dev)",
                events.len()
            ),
            Err(e) => {
                eprintln!("cannot write trace file {path}: {e}");
                code = 1;
            }
        }
    }
    if let Some(path) = args.get("metrics") {
        match std::fs::write(path, ssm_rdu::telemetry::metrics_json(extra_metrics)) {
            Ok(()) => println!("wrote metrics snapshot to {path}"),
            Err(e) => {
                eprintln!("cannot write metrics file {path}: {e}");
                code = 1;
            }
        }
    }
    code
}

/// Tail-latency quantiles and batch shape for the `--metrics` snapshot.
fn metrics_kv(m: &ssm_rdu::coordinator::Metrics) -> Vec<(String, f64)> {
    vec![
        ("latency_p50_us".into(), m.latency_quantile_us(0.5) as f64),
        ("latency_p95_us".into(), m.latency_quantile_us(0.95) as f64),
        ("latency_p99_us".into(), m.latency_p99_us() as f64),
        ("latency_p999_us".into(), m.latency_p999_us() as f64),
        ("token_p50_us".into(), m.token_quantile_us(0.5) as f64),
        ("token_p99_us".into(), m.token_p99_us() as f64),
        ("token_p999_us".into(), m.token_p999_us() as f64),
        ("mean_batch".into(), m.mean_batch_size()),
    ]
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("all");
    let code = match cmd {
        "spec" => {
            figures::table1().print();
            0
        }
        "table2" => {
            figures::platforms::table2().print();
            0
        }
        "table4" => {
            figures::table4().print();
            0
        }
        "fig7" => {
            let f = figures::hyena::fig7_at(&seq_lens(&args));
            f.table().print();
            f.speedup_report().print();
            0
        }
        "fig8" => {
            let f = figures::platforms::fig8_at(&seq_lens(&args));
            f.table().print();
            f.speedup_report().print();
            0
        }
        "fig11" => {
            let f = figures::mamba::fig11_at(&seq_lens(&args));
            f.table().print();
            f.speedup_report().print();
            0
        }
        "fig12" => {
            let f = figures::mamba::fig12_at(*seq_lens(&args).last().unwrap());
            f.table().print();
            f.speedup_report().print();
            0
        }
        "all" => {
            figures::table1().print();
            figures::platforms::table2().print();
            let f7 = figures::fig7();
            f7.table().print();
            f7.speedup_report().print();
            let f8 = figures::fig8();
            f8.table().print();
            f8.speedup_report().print();
            let f11 = figures::fig11();
            f11.table().print();
            f11.speedup_report().print();
            let f12 = figures::fig12();
            f12.table().print();
            f12.speedup_report().print();
            figures::table4().print();
            0
        }
        "simulate" => simulate(&args),
        "debug" => debug(&args),
        "sweep" => sweep(&args),
        "dot" => dot(&args),
        "serve" => serve(&args),
        "fleet" => fleet(&args),
        other => {
            eprintln!(
                "unknown subcommand `{other}`; usage: ssm-rdu \
                 <spec|table2|table4|fig7|fig8|fig11|fig12|all|simulate|debug|sweep|dot|serve|fleet> \
                 [--options] — `simulate`/`sweep`/`serve`/`dot` take --workload/--model with \
                 any registered workload ({}); see README.md (or the rust/src/main.rs doc \
                 block) for the full reference",
                registry_names().join(", ")
            );
            2
        }
    };
    std::process::exit(code);
}

fn seq_lens(args: &Args) -> Vec<usize> {
    args.usize_list_or("seq-lens", &figures::PAPER_SEQ_LENS)
}

/// Single-step a canonical PCU program in the pcusim debugger: run to a
/// breakpoint, dump architectural state, resume, and verify the interrupted
/// run reproduces the batch engine's outputs and `ExecStats` exactly.
fn debug(args: &Args) -> i32 {
    let lanes = args.usize_or("lanes", 32);
    let stages = args.usize_or("stages", 12);
    let vectors = args.usize_or("vectors", 8).max(1);
    let seed = args.usize_or("seed", 42) as u64;
    let name = args.get_or("program", "fused_conv");
    let Some(prog) = pcusim::demo_program(&name, lanes, seed) else {
        eprintln!(
            "unknown --program `{name}`; valid: {}",
            pcusim::programs::DEMO_PROGRAM_NAMES.join(", ")
        );
        return 2;
    };
    let geom = PcuGeometry::new(lanes, stages);
    let pcu = if args.flag("serialized") {
        Pcu::baseline(geom)
    } else {
        Pcu::with_extension(geom, prog.mode)
    };
    let mut rng = XorShift::new(seed ^ 0x5eed);
    let inputs: Vec<Vec<C64>> = (0..vectors)
        .map(|_| {
            (0..lanes)
                .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
                .collect()
        })
        .collect();
    let mut session = pcusim::DebugSession::new(pcu, &prog, inputs.clone());
    println!(
        "debug: {} on {} PCU ({}), {} levels, {} vectors",
        prog.name,
        geom,
        if session.is_spatial() { "spatial" } else { "serialized" },
        prog.levels.len(),
        vectors
    );

    if args.flag("interactive") {
        return debug_repl(&mut session, &pcu, &prog, &inputs);
    }

    // Optional manual single-stepping before the breakpoint run.
    for _ in 0..args.usize_or("step", 0) {
        if session.is_done() {
            break;
        }
        let rep = session.step();
        let computed: Vec<String> = rep
            .computed
            .iter()
            .map(|&(l, v)| format!("v{v}@{}", prog.stage_label(l)))
            .collect();
        let emitted =
            rep.emitted_vector.map(|v| format!("  out v{v}")).unwrap_or_default();
        println!("  cycle {:>4}: [{}]{}", rep.cycle, computed.join(" "), emitted);
    }

    // Register breakpoints and run to the first hit.
    let mut have_break = false;
    if let Some(spec) = args.get("break-stage") {
        let id = session.break_on_label(spec).or_else(|| {
            spec.parse::<usize>()
                .ok()
                .filter(|&i| i < prog.levels.len())
                .map(|i| session.break_on_stage(i))
        });
        if id.is_none() {
            eprintln!(
                "--break-stage `{spec}` names no stage of `{}`; labels: {}",
                prog.name,
                (0..prog.levels.len()).map(|i| prog.stage_label(i)).collect::<Vec<_>>().join(", ")
            );
            return 2;
        }
        have_break = true;
    }
    if let Some(c) = args.get("break-cycle") {
        match c.parse::<u64>() {
            Ok(c) => {
                session.break_on_cycle(c);
                have_break = true;
            }
            Err(_) => {
                eprintln!("--break-cycle wants a cycle number, got `{c}`");
                return 2;
            }
        }
    }

    let mut dumped_snapshot = None;
    if have_break && !session.is_done() {
        match session.run() {
            pcusim::RunOutcome::Break(hit) => {
                let at = hit
                    .stage
                    .map(|s| format!(" at stage {} ({})", s, prog.stage_label(s)))
                    .unwrap_or_default();
                let vec_s = hit.vector.map(|v| format!(", vector {v}")).unwrap_or_default();
                println!("breakpoint {} hit: cycle {}{}{}", hit.id, hit.cycle, at, vec_s);
                dumped_snapshot = Some(session.snapshot());
            }
            pcusim::RunOutcome::Done => println!("run completed before any breakpoint fired"),
            pcusim::RunOutcome::AtCycle(c) => println!("stopped at cycle {c}"),
        }
    }
    if let Some(snap) = &dumped_snapshot {
        if args.flag("dump") {
            print!("{}", snap.render());
        }
        if let Some(path) = args.get("json") {
            if let Err(e) = std::fs::write(path, snap.to_json()) {
                eprintln!("failed to write --json {path}: {e}");
                return 1;
            }
            println!("snapshot written to {path}");
        }
    }
    if args.flag("expect-noc") {
        match &dumped_snapshot {
            Some(snap) if !snap.noc.is_empty() => {
                println!("noc check: {} flits in flight at the break", snap.noc.len());
            }
            Some(_) => {
                eprintln!("--expect-noc: snapshot has no cross-lane traffic");
                return 1;
            }
            None => {
                eprintln!("--expect-noc: no breakpoint snapshot was taken");
                return 1;
            }
        }
    }

    // Resume to completion, counting further breakpoint fires.
    let mut extra_fires = 0u64;
    while !session.is_done() {
        match session.run() {
            pcusim::RunOutcome::Break(_) => extra_fires += 1,
            pcusim::RunOutcome::Done => break,
            pcusim::RunOutcome::AtCycle(_) => unreachable!("run() never reports AtCycle"),
        }
    }
    if extra_fires > 0 {
        println!("resumed through {extra_fires} further breakpoint fire(s)");
    }

    // The debugger must be a faithful re-enactment of the batch engine:
    // interrupted or not, outputs and ExecStats match exactly.
    let (want_out, want_stats) = pcu.run(&prog, &inputs);
    let stats = session.stats().expect("session is done");
    if session.outputs() != &want_out[..] || stats != want_stats {
        eprintln!("MISMATCH: debugger diverged from engine (stats {stats:?} vs {want_stats:?})");
        return 1;
    }
    println!(
        "deterministic resume verified: {} vectors, {} cycles, utilization {:.3}",
        stats.vectors,
        stats.cycles,
        stats.utilization()
    );
    0
}

/// Minimal stdin REPL for `debug --interactive`:
/// `s` step · `c N` run to cycle N · `b LABEL` breakpoint · `r` run ·
/// `dump` snapshot · `stats` final stats · `q` quit.
fn debug_repl(
    session: &mut pcusim::DebugSession<'_>,
    pcu: &Pcu,
    prog: &pcusim::Program,
    inputs: &[Vec<C64>],
) -> i32 {
    use std::io::{BufRead, Write};
    let stdin = std::io::stdin();
    let mut lines = stdin.lock().lines();
    loop {
        print!("(pcudbg) ");
        let _ = std::io::stdout().flush();
        let Some(Ok(line)) = lines.next() else { break };
        let mut words = line.split_whitespace();
        match words.next() {
            Some("s") => {
                if session.is_done() {
                    println!("done");
                } else {
                    let rep = session.step();
                    println!("cycle {} computed {:?}", rep.cycle, rep.computed);
                }
            }
            Some("c") => {
                let target = words.next().and_then(|w| w.parse().ok()).unwrap_or(u64::MAX);
                println!("{:?}", session.run_to(target));
            }
            Some("b") => match words.next() {
                Some(label) => match session.break_on_label(label) {
                    Some(id) => println!("breakpoint {id} on `{label}`"),
                    None => println!("no stage labeled `{label}`"),
                },
                None => println!("usage: b LABEL"),
            },
            Some("r") => {
                if session.is_done() {
                    println!("done");
                } else {
                    println!("{:?}", session.run());
                }
            }
            Some("dump") => print!("{}", session.snapshot().render()),
            Some("stats") => match session.stats() {
                Some(s) => println!("{s:?}"),
                None => println!("not done yet (cycle {})", session.cycle()),
            },
            Some("q") => break,
            Some(other) => println!("unknown command `{other}` (s/c/b/r/dump/stats/q)"),
            None => {}
        }
    }
    // Even an abandoned REPL session must not leave a wrong impression:
    // finish the run and verify against the engine before exiting.
    while !session.is_done() {
        session.step();
    }
    let (want_out, want_stats) = pcu.run(prog, inputs);
    if session.outputs() != &want_out[..] || session.stats() != Some(want_stats) {
        eprintln!("MISMATCH: debugger diverged from engine");
        return 1;
    }
    0
}

/// Demonstrate the PCU simulator: FFT and scan programs on baseline vs
/// extended PCUs, printing regime, throughput and utilization.
fn simulate(args: &Args) -> i32 {
    observability_begin(args);
    let lanes = args.usize_or("lanes", 32);
    let stages = args.usize_or("stages", 12);
    let geom = PcuGeometry::new(lanes, stages);
    let mut rng = XorShift::new(42);
    let batch: Vec<Vec<C64>> = (0..2048)
        .map(|_| (0..lanes).map(|_| C64::real(rng.uniform(-1.0, 1.0))).collect())
        .collect();

    println!("PCU simulator: {geom} geometry, {} input vectors", batch.len());
    let prog = pcusim::fft_program(lanes);
    for (name, pcu) in [("baseline", Pcu::baseline(geom)), ("fft-mode", Pcu::fft_mode(geom))] {
        let (_, stats) = pcu.run(&prog, &batch);
        println!(
            "  {name:9} fft{lanes}:     {} regime, II={:.2} cyc/vec, FU util={:.1}%",
            if stats.spatial { "spatial   " } else { "serialized" },
            stats.initiation_interval(),
            stats.utilization() * 100.0
        );
    }
    let scan = pcusim::hs_scan_program(lanes);
    for (name, pcu) in [("baseline", Pcu::baseline(geom)), ("hs-mode", Pcu::hs_scan_mode(geom))] {
        let (_, stats) = pcu.run(&scan, &batch);
        println!(
            "  {name:9} hs-scan{lanes}: {} regime, II={:.2} cyc/vec, FU util={:.1}%",
            if stats.spatial { "spatial   " } else { "serialized" },
            stats.initiation_interval(),
            stats.utilization() * 100.0
        );
    }
    // Every selected workload's numeric golden model vs its reference path
    // (the registry's per-workload contract; see docs/WORKLOADS.md).
    let wls = match selected_workloads(args) {
        Ok(w) => w,
        Err(code) => return code,
    };
    println!("\nworkload golden models (seed 42):");
    for w in &wls {
        match w.golden_check(42) {
            Some(gc) => println!(
                "  {:9} vs {}: |d|={:.1e}{}",
                w.name(),
                gc.reference,
                gc.max_abs_diff,
                if gc.bit_identical { " (bit-identical)" } else { "" }
            ),
            None => println!("  {:9} (baseline; no golden model)", w.name()),
        }
    }

    let chips = args.usize_or("chips", 1).max(1);
    if args.flag("fuse") {
        fuse_report(args, chips, &wls);
    }
    if chips > 1 {
        shard_report(chips, args.usize_or("seq-len", 1 << 20), &wls);
    }

    // With --trace: lay the pcusim per-cycle stage-occupancy timelines on
    // the trace's pcusim process (1 trace µs = 1 modeled cycle) — the same
    // programs the demo just ran, spatial vs serialized side by side.
    let mut timeline = Vec::new();
    if ssm_rdu::telemetry::enabled() {
        let mut t = 0u64;
        let mut lay = |pcu: &Pcu, prog: &pcusim::Program, vectors: usize| {
            let evs = pcusim::stage_timeline(pcu, prog, vectors, t);
            t = pcusim::timeline_cycles(&evs) + 16;
            timeline.extend(evs);
        };
        lay(&Pcu::fft_mode(geom), &prog, 64);
        lay(&Pcu::baseline(geom), &prog, 8);
        lay(&Pcu::hs_scan_mode(geom), &scan, 64);
        if args.flag("fuse") {
            let h: Vec<C64> = (0..lanes).map(|i| C64::real(1.0 / (i + 1) as f64)).collect();
            let fused = pcusim::fused_conv_program(lanes, &h);
            lay(&Pcu::fft_mode(geom), &fused, 64);
        }
    }
    write_observability(args, timeline, &[])
}

/// `simulate --fuse`: prove the fused pipelines bit-identical to their
/// unfused launch sequences on the cycle-level simulator, then print the
/// fused-vs-unfused DFModel latency table for the selected workloads (and,
/// with `--chips > 1`, the sharded composition).
fn fuse_report(args: &Args, chips: usize, wls: &[&'static dyn Workload]) {
    use ssm_rdu::pcusim::{fused_conv_program, unfused_conv_programs};

    // 1) Cycle-level numerics: the fused FFT→filter→iFFT conv program vs
    //    the same three stages as separate launches — must be bit-identical.
    let lanes = 32;
    let geom = PcuGeometry::table1();
    let pcu = Pcu::fft_mode(geom);
    let mut rng = XorShift::new(17);
    let h: Vec<C64> =
        (0..lanes).map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0))).collect();
    let fused_prog = fused_conv_program(lanes, &h);
    let [p1, p2, p3] = unfused_conv_programs(lanes, &h);
    let mut conv_diff = 0.0f64;
    for _ in 0..64 {
        let x: Vec<C64> = (0..lanes)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let staged = pcu.eval(&p3, &pcu.eval(&p2, &pcu.eval(&p1, &x)));
        let fused = pcu.eval(&fused_prog, &x);
        conv_diff = conv_diff.max(ssm_rdu::util::complex::max_abs_diff_c(&staged, &fused));
    }
    let (_, stats) = pcu.run(&fused_prog, &[vec![C64::real(1.0); lanes]]);
    println!(
        "\nfused conv{lanes} ({} levels, {} regime on fft-mode): fused vs unfused |d|={conv_diff:.1e}",
        fused_prog.levels.len(),
        if stats.spatial { "spatial" } else { "serialized" },
    );

    // 2) Fused scan→gate vs staged scan-then-gate, ragged length, and the
    //    sharded variant when --chips > 1 — also bit-identical.
    let n = 1000;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let z: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
    let staged_gate = ssm_rdu::scan::scan_gate_unfused(&a, &b, &z);
    let d_gate = max_abs_diff(&ssm_rdu::scan::scan_gate_fused(&a, &b, &z), &staged_gate);
    let staged_shard: Vec<f64> = shard::sharded_mamba_scan(&a, &b, chips)
        .iter()
        .zip(&z)
        .map(|(&hh, &zi)| hh * ssm_rdu::scan::silu(zi))
        .collect();
    let d_shard =
        max_abs_diff(&shard::sharded_scan_gate_fused(&a, &b, &z, chips), &staged_shard);
    println!(
        "fused scan+gate (n={n}): vs unfused |d|={d_gate:.1e}, {chips}-chip sharded |d|={d_shard:.1e}"
    );

    // 3) The modeled end-to-end win: fused vs kernel-by-kernel DFModel
    //    latency for the selected workloads.
    let lens = match args.get("seq-len") {
        Some(_) => vec![args.usize_or("seq-len", 1 << 20)],
        None => vec![1 << 12, 1 << 16, 1 << 20],
    };
    figures::fusion_table(&figures::fusion_at_workloads(&lens, wls)).print();

    if chips > 1 {
        let link = InterchipLink::rdu_fabric();
        let l = args.usize_or("seq-len", 1 << 20);
        if l % chips == 0 {
            let dc = DecoderConfig::paper(l);
            for w in wls {
                if w.shard_comm(&dc) == ssm_rdu::workloads::ShardComm::Unsupported {
                    continue;
                }
                let cfg = w.extended_config();
                let f = shard::sharded_estimate_fused_workload(w, &dc, chips, &cfg, &link, true);
                let u = shard::sharded_estimate_fused_workload(w, &dc, chips, &cfg, &link, false);
                if let (Ok(f), Ok(u)) = (f, u) {
                    println!(
                        "{chips}-chip {} @ L={l}: unfused {} -> fused {} ({:.2}x)",
                        w.name(),
                        fmt_time(u.total_seconds),
                        fmt_time(f.total_seconds),
                        u.total_seconds / f.total_seconds,
                    );
                }
            }
        }
    }
}

/// `sweep`: design-space ablations over chip parameters for the selected
/// workloads (`--workload`, default every registered SSM); `--fuse` adds
/// the fusion-gain view.
fn sweep(args: &Args) -> i32 {
    use ssm_rdu::arch::MemTech;
    use ssm_rdu::dfmodel::{sweep_bandwidth, sweep_pcu_count, sweep_stages, sweep_table};

    let wls = match selected_workloads(args) {
        Ok(w) => w,
        Err(code) => return code,
    };
    let l = args.usize_or("seq-len", 1 << 18);
    let dc = DecoderConfig::paper(l);
    let pcus = args.usize_list_or("pcus", &[128, 256, 520]);
    let stages = args.usize_list_or("stages", &[6, 12, 24]);

    let sweeps: [(&str, Vec<ssm_rdu::dfmodel::SweepPoint>); 3] = [
        ("PCU count", sweep_pcu_count(&dc, &pcus, &wls)),
        (
            "DRAM technology",
            sweep_bandwidth(&dc, &[MemTech::Ddr5, MemTech::Hbm2e, MemTech::Hbm3e], &wls),
        ),
        ("pipeline depth", sweep_stages(&dc, &stages, &wls)),
    ];
    for (what, pts) in sweeps {
        sweep_table(&format!("Design sweep over {what} at L={l}"), &pts).print();
    }

    if args.flag("fuse") {
        for (name, gain) in ssm_rdu::dfmodel::fusion_gains(&dc, &wls) {
            println!("fusion gain at L={l}: {name} {gain:.2}x (unfused/fused)");
        }
        figures::fusion_table(&figures::fusion_at_workloads(&[l], &wls)).print();
    }
    0
}

/// `simulate --chips P`: check the sharded dataflows against their
/// single-chip references, then print the strong-scaling sweep for the
/// selected SSM decoders (speedup over one chip and communication share).
fn shard_report(chips: usize, seq_len: usize, wls: &[&'static dyn Workload]) {
    let link = InterchipLink::rdu_fabric();
    // Sweep powers of two up to the requested chip count; a count must
    // divide L (the sharded estimate partitions the sequence evenly), so
    // report and drop any that does not rather than panicking mid-sweep.
    let mut counts = vec![1usize];
    while counts.last().unwrap() * 2 <= chips {
        counts.push(counts.last().unwrap() * 2);
    }
    let (counts, dropped): (Vec<usize>, Vec<usize>) =
        counts.into_iter().partition(|&p| seq_len % p == 0);
    if !dropped.is_empty() {
        eprintln!(
            "note: skipping chip counts {dropped:?} — they do not divide --seq-len {seq_len}"
        );
    }
    let p = *counts.last().unwrap();

    // Numerics first: sharding must not change the math, and the pooled
    // per-chip execution must not change a single bit vs serial.
    let pool = ssm_rdu::runtime::WorkerPool::from_env();
    let mut rng = XorShift::new(9);
    let n = 1000;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let scan_serial = shard::sharded_mamba_scan(&a, &b, p);
    let d_scan = max_abs_diff(&scan_serial, &ssm_rdu::scan::mamba_scan_serial(&a, &b));
    let scan_pooled_ok = shard::sharded_mamba_scan_pooled(&a, &b, p, &pool) == scan_serial;
    let x: Vec<C64> = (0..4096)
        .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
        .collect();
    let fp = p.min(32);
    let variant = ssm_rdu::fft::BaileyVariant::Vector;
    let fft_serial = shard::sharded_bailey_fft(&x, 32, fp, variant);
    let d_fft =
        ssm_rdu::util::complex::max_abs_diff_c(&fft_serial, &ssm_rdu::fft::fft(&x));
    let fft_pooled_ok =
        shard::sharded_bailey_fft_pooled(&x, 32, fp, variant, &pool) == fft_serial;
    println!(
        "\nsharded dataflow numerics: {p}-chip Mamba scan vs serial |d|={d_scan:.2e}, \
         {fp}-chip Bailey FFT vs Cooley-Tukey |d|={d_fft:.2e}"
    );
    println!(
        "pooled execution ({} threads): scan bit-identical: {scan_pooled_ok}, \
         fft bit-identical: {fft_pooled_ok}",
        pool.threads()
    );
    assert!(scan_pooled_ok && fft_pooled_ok, "pooling must not change the numerics");

    // SSD's sharded chunked scan is also exact — and, carry-chained through
    // the same exchange, bit-identical to the serial recurrence.
    let ssd_ok =
        shard::sharded_ssd_scan(&a, &b, p, 256) == ssm_rdu::scan::mamba_scan_serial(&a, &b);
    println!("sharded SSD chunked scan ({p} chips, Q=256) bit-identical to serial: {ssd_ok}");
    assert!(ssd_ok, "the SSD carry chain must preserve serial numerics exactly");

    // Strong scaling at the paper decoder shape over `link` for every
    // selected (shardable) workload, each on its own extended config.
    println!("strong scaling at L={seq_len}, {link}:");
    let dc = DecoderConfig::paper(seq_len);
    for w in wls {
        if w.shard_comm(&dc) == ssm_rdu::workloads::ShardComm::Unsupported {
            continue;
        }
        let cfg = w.extended_config();
        let pts = match shard::strong_scaling_workload(*w, &dc, &counts, &cfg, &link) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("  {}: unmappable ({e})", w.name());
                continue;
            }
        };
        let mut t = ssm_rdu::util::table::Table::new(
            &format!("{} strong scaling on {}", w.name(), cfg.name()),
            &["Chips", "Per-chip", "Comm", "Total", "Speedup", "Comm share"],
        );
        for pt in &pts {
            t.row(&[
                format!("{}", pt.est.chips),
                fmt_time(pt.est.per_chip.total_seconds),
                fmt_time(pt.est.comm_seconds),
                fmt_time(pt.est.total_seconds),
                format!("{:.2}x", pt.speedup),
                format!("{:.1}%", pt.est.comm_share() * 100.0),
            ]);
        }
        t.print();
    }
}

/// Dump a workload graph as graphviz dot. Any registered workload name is
/// valid (`--model` and `--workload` are synonyms here); the error path
/// lists the registry instead of a hardcoded set.
fn dot(args: &Args) -> i32 {
    let l = args.usize_or("seq-len", 1 << 20);
    let dc = DecoderConfig::paper(l);
    let model = args.get("model").or_else(|| args.get("workload")).unwrap_or("hyena").to_string();
    let g = match lookup(&model) {
        Some(w) => w.build_graph(&dc),
        None => {
            eprintln!(
                "unknown model `{model}`; registered workloads: {}",
                registry_names().join(", ")
            );
            return 2;
        }
    };
    println!("{}", g.to_dot());
    0
}

/// Parse a `NODE@FRAC[,NODE@FRAC…]` scenario list (`--drain 0@0.3`):
/// node index, then the event instant as a fraction of the undisturbed
/// run's duration.
fn parse_scenario_list(spec: &str, what: &str) -> Result<Vec<(usize, f64)>, i32> {
    spec.split(',')
        .map(|item| {
            let err = || {
                eprintln!("bad --{what} entry `{item}`; expected NODE@FRAC, e.g. --{what} 0@0.3");
                2
            };
            let (node, frac) = item.trim().split_once('@').ok_or_else(&err)?;
            let node: usize = node.parse().map_err(|_| err())?;
            let frac: f64 = frac.parse().map_err(|_| err())?;
            if !(0.0..=1.0).contains(&frac) {
                return Err(err());
            }
            Ok((node, frac))
        })
        .collect()
}

/// `fleet`: the multi-node serving tier — trace-driven load over a
/// placement router, live migration on drains, checkpointed fail-stop
/// recovery, and an SLO report. Everything runs in modeled time over the
/// MockExecutor; see docs/FLEET.md for the operator guide.
fn fleet(args: &Args) -> i32 {
    use ssm_rdu::fleet::{
        calibrate_single_node, generate, mock_factory, run_fleet, FleetConfig, FleetScenario,
        PlacementPolicy, TraceConfig,
    };

    observability_begin(args);
    let nodes = args.usize_or("nodes", 4).max(1);
    let chips = args.usize_or("chips", 2).max(1);
    let sessions = args.usize_or("sessions", 64).max(1);
    let seed = args.usize_or("seed", 7) as u64;

    let mut cfg = FleetConfig::demo(nodes, chips);
    cfg.seed = seed;
    cfg.checkpointing = !args.flag("no-checkpoint");
    if args.get("cache-mb").is_some() {
        cfg.node_cache_bytes = args.usize_or("cache-mb", 1) * (1 << 20);
    }
    if let Some(p) = args.get("policy") {
        match PlacementPolicy::parse(p) {
            Some(p) => cfg.policy = p,
            None => {
                eprintln!("unknown --policy `{p}`; valid: round-robin, least-loaded, affine");
                return 2;
            }
        }
    }
    match args.get("network").unwrap_or("pcie5") {
        "pcie5" => cfg.network = InterchipLink::pcie5(),
        "fabric" => cfg.network = InterchipLink::rdu_fabric(),
        other => {
            eprintln!("unknown --network `{other}`; valid: fabric, pcie5");
            return 2;
        }
    }
    let drains = match args.get("drain").map(|s| parse_scenario_list(s, "drain")) {
        Some(Ok(v)) => v,
        Some(Err(code)) => return code,
        None => Vec::new(),
    };
    let fails = match args.get("fail").map(|s| parse_scenario_list(s, "fail")) {
        Some(Ok(v)) => v,
        Some(Err(code)) => return code,
        None => Vec::new(),
    };
    for &(node, _) in drains.iter().chain(&fails) {
        if node >= nodes {
            eprintln!("scenario names node {node}, but the fleet has {nodes}");
            return 2;
        }
    }

    // Capacity calibration: one node under full overload sets the offered
    // rate (1.2x its token capacity, in sessions/s) and the default SLO
    // (its overload p50) — scale-free against the modeled step costs.
    let probe_cfg = TraceConfig::poisson(sessions, 1.0, seed);
    let factory = mock_factory();
    let (node_tok_s, probe_p50_us) =
        match calibrate_single_node(&cfg, &generate(&probe_cfg), &factory) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("calibration failed: {e:#}");
                return 1;
            }
        };
    let rate = {
        let r = args.f64_or("rate", 0.0);
        if r > 0.0 {
            r
        } else {
            1.2 * node_tok_s / probe_cfg.mean_decode_tokens().max(1.0)
        }
    };
    cfg.slo_us = {
        let s = args.f64_or("slo-us", 0.0);
        if s > 0.0 {
            s
        } else {
            probe_p50_us
        }
    };
    println!(
        "fleet: {nodes} nodes x {chips} chips, policy {}, network {}, checkpointing {}",
        cfg.policy.name(),
        args.get("network").unwrap_or("pcie5"),
        if cfg.checkpointing { "on" } else { "off" },
    );
    println!(
        "calibration: one node sustains {node_tok_s:.0} tok/s (overload p50 {probe_p50_us:.2} us) \
         -> offering {rate:.1} sessions/s, SLO {:.2} us",
        cfg.slo_us
    );

    let mut code = 0;
    let mut kv: Vec<(String, f64)> = Vec::new();
    for kind in args.get_or("loadgen", "poisson").split(',') {
        let kind = kind.trim();
        let tc = match kind {
            "poisson" => TraceConfig::poisson(sessions, rate, seed),
            "bursty" => TraceConfig::bursty(sessions, rate, seed),
            "diurnal" => TraceConfig::diurnal(sessions, rate, seed),
            other => {
                eprintln!("unknown --loadgen `{other}`; valid: poisson, bursty, diurnal");
                return 2;
            }
        };
        let trace = generate(&tc);
        // Scenario instants are fractions of the undisturbed run, so
        // `--fail 0@0.5` lands mid-run whatever the modeled timescale is.
        let scenario = if drains.is_empty() && fails.is_empty() {
            FleetScenario::default()
        } else {
            let probe = match run_fleet(&cfg, &trace, &FleetScenario::default(), &factory) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("fleet probe run ({kind}) failed: {e:#}");
                    return 1;
                }
            };
            FleetScenario {
                drain: drains.iter().map(|&(n, f)| (probe.sim_seconds * f, n)).collect(),
                fail: fails.iter().map(|&(n, f)| (probe.sim_seconds * f, n)).collect(),
                ..Default::default()
            }
        };
        let r = match run_fleet(&cfg, &trace, &scenario, &factory) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("fleet run ({kind}) failed: {e:#}");
                return 1;
            }
        };
        println!("\n== {kind} trace: {} sessions ==", trace.len());
        println!("{}", r.summary());
        println!(
            "latency: mean {:.2} us, max {:.2} us | router: placed {} refused {} \
             affinity {}/{} | checkpoints: {} writes, {:.1} KiB",
            r.mean_us,
            r.max_us,
            r.router.placed,
            r.router.refused,
            r.router.affinity_hits,
            r.router.affinity_hits + r.router.affinity_spills,
            r.migrations.checkpoint_puts,
            r.migrations.checkpoint_bytes as f64 / 1024.0,
        );
        if r.migrations.migrations + r.migrations.failovers > 0 {
            println!(
                "migration: {} live + {} failover, {:.1} KiB over the link, {} modeled transfer",
                r.migrations.migrations,
                r.migrations.failovers,
                r.migrations.bytes_moved as f64 / 1024.0,
                fmt_time(r.migrations.transfer_seconds),
            );
        }
        print!("{}", r.node_table());
        if cfg.checkpointing && r.lost_sessions > 0 {
            eprintln!(
                "ERROR: {} session(s) lost under checkpointing — drains and fail-stops must \
                 be lossless",
                r.lost_sessions
            );
            code = 1;
        }
        kv = vec![
            (format!("fleet_{kind}_p50_us"), r.p50_us),
            (format!("fleet_{kind}_p99_us"), r.p99_us),
            (format!("fleet_{kind}_p999_us"), r.p999_us),
            (format!("fleet_{kind}_throughput_tok_s"), r.throughput_tok_s),
            (format!("fleet_{kind}_goodput_tok_s"), r.goodput_tok_s),
            (format!("fleet_{kind}_slo_attainment"), r.slo_attainment),
            (format!("fleet_{kind}_lost_sessions"), r.lost_sessions as f64),
        ]
        .into_iter()
        .chain(kv)
        .collect();
    }
    let obs = write_observability(args, Vec::new(), &kv);
    if code != 0 {
        code
    } else {
        obs
    }
}

/// Serve synthetic batched requests through the PJRT runtime, or — with
/// `--continuous` — live decode sessions through the continuous-batching
/// session subsystem (MockExecutor; per-token kernels are not AOT-lowered).
fn serve(args: &Args) -> i32 {
    if args.flag("continuous") {
        return serve_continuous(args);
    }
    observability_begin(args);
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let n = args.usize_or("requests", 32);
    let workers = args.usize_or("workers", 1);
    let max_batch = args.usize_or("max-batch", 4);
    let wait_ms = args.usize_or("max-wait-ms", 5);

    println!("loading artifacts from {} …", dir.display());
    // Shape probe (cheap manifest read) before spinning up workers.
    let manifest = match ssm_rdu::runtime::Manifest::load(dir.join("manifest.json")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("cannot read manifest: {e:#}\nhint: run `make artifacts` first");
            return 1;
        }
    };
    let elems = manifest.seq_len * manifest.d_model;
    let models: Vec<ModelKind> = manifest.models.keys().copied().collect();

    let dir2 = dir.clone();
    let coord = match Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms as u64) },
            workers,
            ..Default::default()
        },
        Box::new(move || {
            let exec = PjrtExecutor::load(&dir2)?;
            Ok(Box::new(exec) as Box<dyn Executor>)
        }),
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start coordinator: {e:#}");
            return 1;
        }
    };

    println!("serving {n} requests round-robin over {models:?} …");
    let mut rng = XorShift::new(7);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let model = models[i % models.len()];
            let input: Vec<f32> = (0..elems).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            coord.submit(model, input).expect("submit")
        })
        .collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "done: {ok}/{n} ok in {} ({:.1} req/s)  |  {}",
        fmt_time(wall.as_secs_f64()),
        ok as f64 / wall.as_secs_f64(),
        coord.metrics.summary()
    );
    let kv = metrics_kv(&coord.metrics);
    coord.shutdown();

    // Tie the serving stack back to the paper's performance model: print the
    // modeled-RDU latency for the selected workloads (`--workload`, default
    // every registered SSM) at the artifact shape, and — with --chips — the
    // sequence-sharded multi-chip deployment.
    let wls = match selected_workloads(args) {
        Ok(w) => w,
        Err(code) => return code,
    };
    let chips = args.usize_or("chips", 1).max(1);
    let dc = DecoderConfig::paper(manifest.seq_len);
    for w in &wls {
        let (name, g, cfg) = (w.name(), w.build_graph(&dc), w.extended_config());
        if let Ok(est) = ssm_rdu::dfmodel::estimate(&g, &cfg) {
            println!(
                "modeled {} latency for {name} @ L={}: {}",
                cfg.name(),
                manifest.seq_len,
                fmt_time(est.total_seconds)
            );
            println!("  cycle attribution: {}", est.attribution().summary());
        }
        if args.flag("fuse") {
            if let (Ok(f), Ok(u)) = (
                ssm_rdu::dfmodel::estimate_fused(&g, &cfg),
                ssm_rdu::dfmodel::estimate_unfused(&g, &cfg),
            ) {
                println!(
                    "  launch-granularity: unfused {} -> fused {} ({:.2}x, {} -> {} launches)",
                    fmt_time(u.total_seconds),
                    fmt_time(f.total_seconds),
                    u.total_seconds / f.total_seconds,
                    u.sections,
                    f.sections,
                );
            }
        }
    }
    if chips > 1 && manifest.seq_len % chips != 0 {
        eprintln!(
            "note: skipping the {chips}-chip sharded report — {chips} does not divide the \
             artifact seq_len {}",
            manifest.seq_len
        );
    }
    if chips > 1 && manifest.seq_len % chips == 0 {
        let link = InterchipLink::rdu_fabric();
        for w in &wls {
            if w.shard_comm(&dc) == ssm_rdu::workloads::ShardComm::Unsupported {
                continue;
            }
            let cfg = w.extended_config();
            if let Ok(s) = shard::sharded_estimate_workload(*w, &dc, chips, &cfg, &link) {
                println!(
                    "modeled {chips}-chip {} @ L={}: {} per chip + {} exchange = {} \
                     ({:.1}% comm)",
                    w.name(),
                    manifest.seq_len,
                    fmt_time(s.per_chip.total_seconds),
                    fmt_time(s.comm_seconds),
                    fmt_time(s.total_seconds),
                    s.comm_share() * 100.0,
                );
                println!("  cycle attribution: {}", s.attribution().summary());
            }
        }
    }
    write_observability(args, Vec::new(), &kv)
}

/// `serve --continuous`: N live sessions stream K tokens each through the
/// session subsystem (scheduler + state cache) over the worker pool.
fn serve_continuous(args: &Args) -> i32 {
    observability_begin(args);
    let sessions = args.usize_or("sessions", 96);
    let decode_steps = args.usize_or("decode-steps", 32);
    let chips = args.usize_or("chips", 1).max(1);
    let workers = args.usize_or("workers", chips.max(2));
    let max_batch = args.usize_or("max-batch", 16);
    let layers = args.usize_or("layers", 8);
    let d_state = args.usize_or("d-state", 16);
    let d_model = args.usize_or("state-d-model", 64);
    let fft_points = args.usize_or("fft-points", 256);
    let timeout_ms = args.usize_or("session-timeout-ms", 30_000);

    let mamba_shape = StateShape::mamba(layers, d_state, d_model);
    let hyena_shape = StateShape::hyena(layers, d_model, fft_points);
    let model_of = |i: usize| if i % 2 == 0 { ModelKind::Mamba } else { ModelKind::Hyena };
    let footprint: usize = (0..sessions)
        .map(|i| {
            if model_of(i) == ModelKind::Mamba {
                mamba_shape.bytes()
            } else {
                hyena_shape.bytes()
            }
        })
        .sum();
    // Default fleet budget: half the footprint, so the demo exercises
    // eviction. --cache-mb sets the fleet-wide budget; each chip owns an
    // equal share, floored at one state so decode can make progress.
    let fleet_budget = match args.get("cache-mb") {
        Some(_) => args.usize_or("cache-mb", 8) * (1 << 20),
        None => footprint / 2,
    };
    let budget_bytes =
        (fleet_budget / chips).max(mamba_shape.bytes().max(hyena_shape.bytes()));
    println!(
        "continuous serving: {sessions} sessions × {decode_steps} tokens, {workers} workers, \
         batch {max_batch}, {chips} chip(s)"
    );
    println!(
        "state footprint {:.1} KiB vs cache budget {:.1} KiB ({chips} × {:.1} KiB/chip — {})",
        footprint as f64 / 1024.0,
        (budget_bytes * chips) as f64 / 1024.0,
        budget_bytes as f64 / 1024.0,
        if budget_bytes * chips < footprint { "expect spills" } else { "fully resident" }
    );

    let cc = ContinuousConfig {
        sched: SchedulerConfig {
            max_batch,
            session_timeout: Duration::from_millis(timeout_ms as u64),
        },
        budget_bytes,
        mamba_shape,
        hyena_shape,
        chips,
    };
    let coord = match Coordinator::start(
        CoordinatorConfig {
            workers,
            max_inflight: sessions.max(1) * 2,
            continuous: Some(cc),
            ..Default::default()
        },
        Box::new(move || Ok(Box::new(MockExecutor::new(1, d_model)) as Box<dyn Executor>)),
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start coordinator: {e:#}");
            return 1;
        }
    };

    let mut rng = XorShift::new(11);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..sessions)
        .map(|i| {
            let prompt: Vec<f32> =
                (0..d_model * 4).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            coord.submit_session(model_of(i), prompt, decode_steps).expect("submit_session")
        })
        .collect();
    let mut tokens = 0u64;
    let mut complete = 0usize;
    for rx in rxs {
        let mut got = 0usize;
        while rx.recv().is_ok() {
            got += 1;
            tokens += 1;
        }
        if got == decode_steps {
            complete += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "done: {complete}/{sessions} sessions complete, {tokens} tokens in {} ({:.0} tok/s)",
        fmt_time(wall.as_secs_f64()),
        tokens as f64 / wall.as_secs_f64()
    );
    println!("metrics: {}", coord.metrics.summary());
    if let Some(cs) = coord.cache_stats() {
        println!(
            "cache: hits={} misses={} evictions={} restores={} spilled={:.1} KiB \
             restored={:.1} KiB peak_resident={:.1} KiB hit_rate={:.1}% spill_time={}",
            cs.hits,
            cs.misses,
            cs.evictions,
            cs.restores,
            cs.spilled_bytes as f64 / 1024.0,
            cs.restored_bytes as f64 / 1024.0,
            cs.peak_resident_bytes as f64 / 1024.0,
            cs.hit_rate() * 100.0,
            fmt_time(cs.spill_seconds),
        );
    }
    if chips > 1 {
        if let Some(per_chip) = coord.chip_cache_stats() {
            for (chip, cs) in per_chip.iter().enumerate() {
                println!(
                    "  chip {chip}: hits={} misses={} evictions={} restores={} \
                     spilled={:.1} KiB restored={:.1} KiB peak_resident={:.1} KiB",
                    cs.hits,
                    cs.misses,
                    cs.evictions,
                    cs.restores,
                    cs.spilled_bytes as f64 / 1024.0,
                    cs.restored_bytes as f64 / 1024.0,
                    cs.peak_resident_bytes as f64 / 1024.0,
                );
            }
        }
    }
    if let Some(ss) = coord.scheduler_stats() {
        println!(
            "scheduler: admitted={} retired={} expired={} failed={} prefill_steps={} \
             decode_steps={} batches={}",
            ss.admitted, ss.retired, ss.expired, ss.failed, ss.prefill_steps, ss.decode_steps,
            ss.batches,
        );
    }
    // Tie back to the paper's performance model: the modeled per-token
    // decode-step latency for these shapes on the extended RDU.
    for (model, shape, cfg) in [
        (ModelKind::Mamba, &mamba_shape, RduConfig::hs_scan_mode()),
        (ModelKind::Hyena, &hyena_shape, RduConfig::fft_mode()),
    ] {
        let dc = DecoderConfig {
            seq_len: 1,
            d_model: shape.d_model,
            mlp_mult: 4,
            dtype_bytes: 2.0,
            fft_tile: 32,
            state_dim: shape.d_state.max(1),
            expand: 1,
            ssd_chunk: 256,
        };
        let cost = ssm_rdu::dfmodel::decode_step(model, &dc, shape.layers, &cfg);
        println!(
            "modeled {model} decode step on {}: {} ({:.0} cycles, state {:.1} KiB/step)",
            cfg.name(),
            fmt_time(cost.seconds),
            cost.cycles,
            cost.state_bytes / 1024.0,
        );
        if args.flag("fuse") {
            let unf = ssm_rdu::dfmodel::decode_step_unfused(model, &dc, shape.layers, &cfg);
            println!(
                "  kernel-by-kernel decode would cost {} ({:.1}x) — the resident fused \
                 pipeline amortizes {} launches/step",
                fmt_time(unf.seconds),
                unf.seconds / cost.seconds,
                (shape.layers as f64 * ssm_rdu::dfmodel::DECODE_KERNELS_PER_LAYER) as usize,
            );
        }
        if chips > 1 {
            let s = ssm_rdu::dfmodel::decode_step_sharded(
                model,
                &dc,
                shape.layers,
                &cfg,
                chips,
                &InterchipLink::rdu_fabric(),
            );
            println!(
                "  sharded over {chips} chips: {} per chip + {} all-reduce = {} \
                 (state {:.1} KiB/chip)",
                fmt_time(s.per_chip.seconds),
                fmt_time(s.comm_seconds),
                fmt_time(s.seconds),
                s.per_chip.state_bytes / 1024.0,
            );
        }
    }
    let kv = metrics_kv(&coord.metrics);
    coord.shutdown();
    let obs = write_observability(args, Vec::new(), &kv);
    if complete == sessions {
        obs
    } else {
        1
    }
}
