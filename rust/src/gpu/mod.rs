//! Analytical GPU performance model (paper Tables II/III, Figs. 8/12).
//!
//! The GPU executes **kernel-by-kernel** (paper Fig. 1C): each kernel loads
//! its inputs from DRAM, computes, and stores its outputs back — every
//! intermediate tensor is staged through HBM. Per kernel the time is the
//! roofline `max(compute, memory)`; kernels do not overlap, so the total is
//! the sum.
//!
//! Compute rates follow the paper's core split: GEMM-shaped kernels run on
//! tensor cores (311.87 TFLOPS FP16), everything else — FFT butterflies,
//! scans, softmax, element-wise — runs on CUDA cores at ¼ that rate
//! (77.97 TFLOPS). The C-scan is inherently serial on the GPU too.

use crate::arch::GpuSpec;
use crate::graph::{Graph, OpClass};
use std::collections::BTreeMap;

/// NVIDIA A100 boost clock, for the serial C-scan latency (1 update/cycle).
const A100_CLOCK_HZ: f64 = 1.41e9;

/// Per-kernel line item.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuKernelEstimate {
    pub name: String,
    pub op: OpClass,
    pub flops: f64,
    pub compute_seconds: f64,
    pub memory_seconds: f64,
    /// max(compute, memory) — the kernel's roofline time.
    pub seconds: f64,
    /// Whether this kernel ran on tensor cores.
    pub tensor_core: bool,
}

/// Kernel-by-kernel estimate for a whole graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuEstimate {
    pub graph_name: String,
    pub gpu_name: String,
    pub total_seconds: f64,
    pub compute_seconds: f64,
    pub memory_seconds: f64,
    pub kernels: Vec<GpuKernelEstimate>,
}

impl GpuEstimate {
    /// Latency grouped by op class (Fig. 8/12 breakdown view).
    pub fn breakdown_by_op(&self) -> BTreeMap<&'static str, f64> {
        let mut m = BTreeMap::new();
        for k in &self.kernels {
            *m.entry(k.op.label()).or_insert(0.0) += k.seconds;
        }
        m
    }

    /// Fraction of kernel time that is memory-bound — the kernel-fusion
    /// argument of paper §I ("intermediate results … staged in off-chip
    /// memory, incurring significant latency and energy overheads").
    pub fn memory_bound_fraction(&self) -> f64 {
        if self.total_seconds == 0.0 {
            return 0.0;
        }
        self.kernels
            .iter()
            .filter(|k| k.memory_seconds >= k.compute_seconds)
            .map(|k| k.seconds)
            .sum::<f64>()
            / self.total_seconds
    }
}

/// Peak FLOP/s the GPU offers a kernel of class `op`.
pub fn peak_for(op: OpClass, spec: &GpuSpec) -> f64 {
    if op.gpu_tensor_core() {
        spec.tensor_flops
    } else {
        spec.cuda_flops
    }
}

/// Estimate kernel-by-kernel execution of `g` on `spec`.
pub fn estimate(g: &Graph, spec: &GpuSpec) -> GpuEstimate {
    let bw = spec.dram.bandwidth();
    let mut kernels = Vec::with_capacity(g.kernels.len());
    let mut total = 0.0;
    let mut total_c = 0.0;
    let mut total_m = 0.0;

    for k in &g.kernels {
        let compute = match k.op {
            // Serial scan: one element-update per cycle regardless of
            // parallel hardware (paper §IV-A).
            OpClass::ScanSerial => k.elements * k.channels / A100_CLOCK_HZ,
            op => k.flops / peak_for(op, spec),
        };
        // Kernel-by-kernel: inputs + outputs + weights all cross DRAM.
        let memory = (k.input_bytes + k.output_bytes + k.weight_bytes) / bw;
        let seconds = compute.max(memory);
        total += seconds;
        total_c += compute;
        total_m += memory;
        kernels.push(GpuKernelEstimate {
            name: k.name.clone(),
            op: k.op,
            flops: k.flops,
            compute_seconds: compute,
            memory_seconds: memory,
            seconds,
            tensor_core: k.op.gpu_tensor_core(),
        });
    }

    GpuEstimate {
        graph_name: g.name.clone(),
        gpu_name: spec.name.clone(),
        total_seconds: total,
        compute_seconds: total_c,
        memory_seconds: total_m,
        kernels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::BaileyVariant;
    use crate::workloads::{hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant};

    fn cfg() -> DecoderConfig {
        DecoderConfig::paper(1 << 20)
    }

    #[test]
    fn vector_fft_slower_than_gemm_fft_on_gpu() {
        // Paper §III-A/C: GEMM-FFT exists because tensor cores beat CUDA
        // cores even at 6.4× the FLOPs... but at 4× the rate the net effect
        // at the whole-decoder level favors Vector-FFT only if memory
        // doesn't dominate. Check the per-transform compute relation:
        let spec = GpuSpec::a100();
        let gv = estimate(&hyena_decoder(&cfg(), BaileyVariant::Vector), &spec);
        let gg = estimate(&hyena_decoder(&cfg(), BaileyVariant::Gemm), &spec);
        let fft_c_v: f64 = gv
            .kernels
            .iter()
            .filter(|k| k.op == OpClass::VectorFft)
            .map(|k| k.compute_seconds)
            .sum();
        let fft_c_g: f64 = gg
            .kernels
            .iter()
            .filter(|k| k.op == OpClass::GemmFft)
            .map(|k| k.compute_seconds)
            .sum();
        // 6.4× FLOPs at 4× rate → GEMM-FFT ≈ 1.6× the compute time.
        let r = fft_c_g / fft_c_v;
        assert!((r - 1.6).abs() < 0.05, "r={r}");
    }

    #[test]
    fn tensor_core_assignment() {
        let e = estimate(&hyena_decoder(&cfg(), BaileyVariant::Gemm), &GpuSpec::a100());
        for k in &e.kernels {
            assert_eq!(k.tensor_core, k.op.gpu_tensor_core(), "{}", k.name);
        }
    }

    #[test]
    fn total_is_sum_of_kernels() {
        let e = estimate(&mamba_decoder(&cfg(), ScanVariant::Parallel), &GpuSpec::a100());
        let sum: f64 = e.kernels.iter().map(|k| k.seconds).sum();
        assert!((e.total_seconds - sum).abs() / sum < 1e-12);
    }

    #[test]
    fn staging_makes_some_kernels_memory_bound() {
        // Element-wise kernels at 1M sequence length are memory-bound under
        // kernel-by-kernel execution — the fusion argument.
        let e = estimate(&hyena_decoder(&cfg(), BaileyVariant::Vector), &GpuSpec::a100());
        assert!(e.memory_bound_fraction() > 0.1, "frac={}", e.memory_bound_fraction());
    }

    #[test]
    fn serial_scan_dominates_cscan_mamba_on_gpu() {
        let e = estimate(&mamba_decoder(&cfg(), ScanVariant::CScan), &GpuSpec::a100());
        let scan = e.kernels.iter().find(|k| k.op == OpClass::ScanSerial).unwrap();
        assert!(scan.seconds / e.total_seconds > 0.9);
    }
}
