//! Process-wide named monotonic counters with snapshot exporters.
//!
//! Counters are always on: an increment is one relaxed `fetch_add`, cheap
//! enough to leave enabled everywhere. Registration goes through a locked
//! registry, so hot call sites resolve their counter once (cache the
//! `&'static AtomicU64` in a `OnceLock`) and pay only the atomic add in
//! steady state — see [`crate::fft::plan::with_conv_plan`] for the idiom.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

fn registry() -> &'static Mutex<BTreeMap<&'static str, &'static AtomicU64>> {
    static REG: OnceLock<Mutex<BTreeMap<&'static str, &'static AtomicU64>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Look up (registering on first use) the counter named `name`. The
/// returned reference is `'static`: resolve once, increment forever.
pub fn counter(name: &'static str) -> &'static AtomicU64 {
    let mut reg = registry().lock().expect("counter registry lock");
    reg.entry(name).or_insert_with(|| Box::leak(Box::new(AtomicU64::new(0))))
}

/// Current value of every registered counter, sorted by name.
pub fn snapshot() -> Vec<(&'static str, u64)> {
    let reg = registry().lock().expect("counter registry lock");
    reg.iter().map(|(name, c)| (*name, c.load(Ordering::Relaxed))).collect()
}

/// Plain-text export: one `name value` line per counter.
pub fn snapshot_text() -> String {
    let mut out = String::new();
    for (name, value) in snapshot() {
        out.push_str(&format!("{name} {value}\n"));
    }
    out
}

/// JSON metrics document: every registered counter under `"counters"`,
/// plus caller-supplied scalar gauges (quantiles, cache totals, ...)
/// under `"metrics"`. Backs the CLI's `--metrics <file>` flag; parses
/// with [`crate::util::json::Json`].
pub fn metrics_json(extra: &[(String, f64)]) -> String {
    let mut out = String::from("{\n  \"schema\": \"ssm-rdu-metrics-v1\",\n  \"counters\": {");
    let counters = snapshot();
    for (i, (name, value)) in counters.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n    \"{name}\": {value}"));
    }
    if !counters.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("},\n  \"metrics\": {");
    for (i, (name, value)) in extra.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if value.is_finite() {
            out.push_str(&format!("\n    \"{name}\": {value}"));
        } else {
            out.push_str(&format!("\n    \"{name}\": null"));
        }
    }
    if !extra.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("}\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn counters_register_and_accumulate() {
        let c = counter("test.counters.alpha");
        let before = c.load(Ordering::Relaxed);
        c.fetch_add(3, Ordering::Relaxed);
        assert_eq!(counter("test.counters.alpha").load(Ordering::Relaxed), before + 3);
        // Same name, same cell.
        assert!(std::ptr::eq(c, counter("test.counters.alpha")));
    }

    #[test]
    fn snapshot_is_sorted_and_text_lists_every_counter() {
        counter("test.counters.a").fetch_add(1, Ordering::Relaxed);
        counter("test.counters.b").fetch_add(2, Ordering::Relaxed);
        let snap = snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| *n).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted);
        let text = snapshot_text();
        assert!(text.lines().any(|l| l.starts_with("test.counters.a ")));
    }

    #[test]
    fn metrics_json_parses_and_carries_extras() {
        counter("test.counters.json").fetch_add(7, Ordering::Relaxed);
        let doc = metrics_json(&[("latency_p99_us".to_string(), 123.5), ("bad".to_string(), f64::NAN)]);
        let j = Json::parse(&doc).expect("metrics JSON must parse");
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("ssm-rdu-metrics-v1"));
        let counters = j.get("counters").expect("counters object");
        assert!(counters.get("test.counters.json").and_then(Json::as_f64).unwrap_or(0.0) >= 7.0);
        let metrics = j.get("metrics").expect("metrics object");
        assert_eq!(metrics.get("latency_p99_us").and_then(Json::as_f64), Some(123.5));
        assert_eq!(metrics.get("bad"), Some(&Json::Null));
    }

    #[test]
    fn empty_registry_sections_are_valid_json() {
        // Even with no extras the document must parse.
        let j = Json::parse(&metrics_json(&[])).expect("parse");
        assert!(j.get("metrics").is_some());
    }
}
