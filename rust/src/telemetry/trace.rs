//! Lock-cheap span/event recorder emitting Chrome trace-event JSON.
//!
//! Design (see the module docs in [`crate::telemetry`] for the track
//! layout and overhead contract):
//!
//! * A single process-global `AtomicBool` gates everything. Disabled call
//!   sites pay one relaxed load and a branch — no clock read, no
//!   allocation, no lock.
//! * Timestamps are nanoseconds from a lazily-pinned monotonic epoch
//!   (`Instant`), so traces from all threads share one clock.
//! * Events buffer in a thread-local `Vec` and flush to the global sink
//!   when the buffer fills, when the thread exits (via the buffer's `Drop`
//!   — scoped worker threads flush before `thread::scope` returns), or on
//!   [`drain`].
//! * Span names are `&'static str` in the hot recorder (zero allocation);
//!   only offline exports like [`crate::pcusim::stage_timeline`] build
//!   owned names, which `Cow` carries without taxing the hot path.

use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Process id of host (wall-time) tracks in the emitted trace.
pub const PID_HOST: u32 = 0;
/// Process id of modeled-cycle tracks (pcusim timelines: 1 µs = 1 cycle).
pub const PID_PCUSIM: u32 = 1;

/// Chip tracks live far above any plausible thread id so the two ranges
/// can never collide.
const CHIP_TRACK_BASE: u64 = 1 << 32;

/// The per-chip track id for instant events (cache spill/restore, carry
/// and transpose exchange markers) attributed to `chip`.
pub fn chip_track(chip: usize) -> u64 {
    CHIP_TRACK_BASE + chip as u64
}

/// Node tracks sit one power of two above the chip range: a fleet of
/// `2^32` chips would be needed before the ranges meet.
const NODE_TRACK_BASE: u64 = 1 << 33;

/// The per-node track id for fleet instant events (session placement,
/// migration out/in, drain, fail-stop) attributed to `node`. Chips of node
/// `n` keep their own [`chip_track`]s (the fleet numbers them globally as
/// `n * chips_per_node + c`); the node track carries router-level events.
pub fn node_track(node: usize) -> u64 {
    NODE_TRACK_BASE + node as u64
}

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is tracing currently enabled? One relaxed load — this is the whole
/// disabled-mode cost of every instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on. Pins the trace epoch on first call so all
/// subsequent timestamps are relative to it.
pub fn enable() {
    epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn the recorder off. Already-buffered events stay until [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

#[inline]
fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// How an event renders in the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A duration span (`ph:"X"`): has a start and a length.
    Span,
    /// A point-in-time marker (`ph:"i"`, thread-scoped).
    Instant,
}

/// One recorded event. `ts_ns`/`dur_ns` are nanoseconds from the trace
/// epoch; the JSON writer converts to the microseconds Perfetto expects.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: Cow<'static, str>,
    pub cat: &'static str,
    pub kind: EventKind,
    pub pid: u32,
    pub tid: u64,
    pub ts_ns: u64,
    pub dur_ns: u64,
    /// Up to two numeric arguments, rendered under `args` in the JSON.
    pub args: [Option<(&'static str, f64)>; 2],
}

// ---------------------------------------------------------------------------
// Sink: thread-local buffers draining into one global Vec.
// ---------------------------------------------------------------------------

const FLUSH_AT: usize = 1024;

fn sink() -> &'static Mutex<Vec<TraceEvent>> {
    static SINK: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

fn next_tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

struct LocalBuf {
    tid: u64,
    events: Vec<TraceEvent>,
}

impl LocalBuf {
    fn new() -> Self {
        let tid = next_tid();
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"));
        name_track(PID_HOST, tid, name);
        Self { tid, events: Vec::with_capacity(FLUSH_AT) }
    }

    fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let mut sink = sink().lock().expect("trace sink lock");
        sink.append(&mut self.events);
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf::new());
}

fn record(tid: Option<u64>, mut ev: TraceEvent) {
    LOCAL.with(|cell| {
        let mut buf = cell.borrow_mut();
        ev.tid = tid.unwrap_or(buf.tid);
        buf.events.push(ev);
        if buf.events.len() >= FLUSH_AT {
            buf.flush();
        }
    });
}

/// Flush the calling thread's buffered events into the global sink.
pub fn flush_thread() {
    LOCAL.with(|cell| cell.borrow_mut().flush());
}

/// Take every recorded event (flushing the calling thread first). Worker
/// threads flush when they exit, so draining after a pooled region joins
/// sees the workers' events too.
pub fn drain() -> Vec<TraceEvent> {
    flush_thread();
    let mut sink = sink().lock().expect("trace sink lock");
    std::mem::take(&mut *sink)
}

// ---------------------------------------------------------------------------
// Track names.
// ---------------------------------------------------------------------------

fn tracks() -> &'static Mutex<BTreeMap<(u32, u64), String>> {
    static TRACKS: OnceLock<Mutex<BTreeMap<(u32, u64), String>>> = OnceLock::new();
    TRACKS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Register a display name for a `(pid, tid)` track. First registration
/// wins; later calls for the same track are no-ops, so every site that
/// *might* own a track can name it without coordination.
pub fn name_track(pid: u32, tid: u64, name: impl Into<String>) {
    let mut map = tracks().lock().expect("track registry lock");
    map.entry((pid, tid)).or_insert_with(|| name.into());
}

// ---------------------------------------------------------------------------
// Recording API.
// ---------------------------------------------------------------------------

/// A RAII span: records one `X` event covering its own lifetime on the
/// current thread's track when it drops. When tracing is disabled the
/// guard is inert and construction costs one atomic load.
#[must_use = "a span measures its guard's lifetime; bind it with `let _t = ...`"]
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    tid: Option<u64>,
    args: [Option<(&'static str, f64)>; 2],
    active: bool,
}

/// Open a span named `name` in category `cat`.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name, cat, start_ns: 0, tid: None, args: [None, None], active: false };
    }
    SpanGuard { name, cat, start_ns: now_ns(), tid: None, args: [None, None], active: true }
}

impl SpanGuard {
    /// Attach a numeric argument (at most two per span; extras are
    /// silently dropped).
    pub fn arg(mut self, key: &'static str, value: f64) -> Self {
        if self.active {
            if let Some(slot) = self.args.iter_mut().find(|s| s.is_none()) {
                *slot = Some((key, value));
            }
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_ns();
        record(
            self.tid,
            TraceEvent {
                name: Cow::Borrowed(self.name),
                cat: self.cat,
                kind: EventKind::Span,
                pid: PID_HOST,
                tid: 0, // resolved by `record`
                ts_ns: self.start_ns,
                dur_ns: end.saturating_sub(self.start_ns),
                args: self.args,
            },
        );
    }
}

/// Record a point-in-time marker on the current thread's track.
#[inline]
pub fn instant(cat: &'static str, name: &'static str) {
    if enabled() {
        record_instant(None, cat, name, None);
    }
}

/// [`instant`] with one numeric argument.
#[inline]
pub fn instant_arg(cat: &'static str, name: &'static str, key: &'static str, value: f64) {
    if enabled() {
        record_instant(None, cat, name, Some((key, value)));
    }
}

/// [`instant_arg`] on an explicit track — used for per-chip attribution
/// (cache spills, exchange markers) where the owning chip, not the
/// executing thread, is the interesting axis.
#[inline]
pub fn instant_on(cat: &'static str, name: &'static str, tid: u64, key: &'static str, value: f64) {
    if enabled() {
        record_instant(Some(tid), cat, name, Some((key, value)));
    }
}

fn record_instant(
    tid: Option<u64>,
    cat: &'static str,
    name: &'static str,
    arg: Option<(&'static str, f64)>,
) {
    let ts = now_ns();
    record(
        tid,
        TraceEvent {
            name: Cow::Borrowed(name),
            cat,
            kind: EventKind::Instant,
            pid: PID_HOST,
            tid: 0, // resolved by `record`
            ts_ns: ts,
            dur_ns: 0,
            args: [arg, None],
        },
    );
}

// ---------------------------------------------------------------------------
// Chrome trace-event JSON writer.
// ---------------------------------------------------------------------------

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn push_num(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null");
    }
}

fn push_args(out: &mut String, args: &[Option<(&'static str, f64)>; 2]) {
    if args[0].is_none() && args[1].is_none() {
        return;
    }
    out.push_str(",\"args\":{");
    let mut first = true;
    for (key, value) in args.iter().flatten() {
        if !first {
            out.push(',');
        }
        first = false;
        out.push('"');
        out.push_str(&esc(key));
        out.push_str("\":");
        push_num(out, *value);
    }
    out.push('}');
}

/// Serialize events as a Chrome trace-event document:
/// `{"displayTimeUnit":"ms","traceEvents":[...]}` with metadata events
/// naming every registered process and thread track first. Timestamps are
/// emitted in microseconds (fractional, from the nanosecond record), the
/// unit Perfetto expects. The output round-trips through
/// [`crate::util::json::Json::parse`].
pub fn trace_json(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(128 + events.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut meta = |out: &mut String, name: &str, pid: u32, tid: u64, value: &str| {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{name}\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            esc(value)
        ));
    };
    let pids: std::collections::BTreeSet<u32> = events
        .iter()
        .map(|e| e.pid)
        .chain(tracks().lock().expect("track registry lock").keys().map(|(p, _)| *p))
        .collect();
    for pid in pids {
        let pname = match pid {
            PID_HOST => "ssm-rdu host",
            PID_PCUSIM => "pcusim (1 trace µs = 1 modeled cycle)",
            _ => "ssm-rdu",
        };
        meta(&mut out, "process_name", pid, 0, pname);
    }
    {
        let map = tracks().lock().expect("track registry lock");
        for ((pid, tid), name) in map.iter() {
            meta(&mut out, "thread_name", *pid, *tid, name);
        }
    }
    for e in events {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{}\",\"pid\":{},\"tid\":{},\"ts\":{}",
            esc(&e.name),
            esc(e.cat),
            match e.kind {
                EventKind::Span => "X",
                EventKind::Instant => "i",
            },
            e.pid,
            e.tid,
            e.ts_ns as f64 / 1000.0,
        ));
        match e.kind {
            EventKind::Span => out.push_str(&format!(",\"dur\":{}", e.dur_ns as f64 / 1000.0)),
            EventKind::Instant => out.push_str(",\"s\":\"t\""),
        }
        push_args(&mut out, &e.args);
        out.push('}');
    }
    out.push_str("]}");
    out
}

/// Write `events` to `path` as a Perfetto-loadable trace file.
pub fn write_trace(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    std::fs::write(path, trace_json(events))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// Recorder state is process-global; unit tests serialize on this and
    /// drain at entry so the parallel test runner cannot interleave them.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = lock();
        drain();
        assert!(!enabled());
        {
            let _t = span("test", "noop").arg("x", 1.0);
        }
        instant_arg("test", "noop", "x", 2.0);
        assert!(drain().is_empty());
    }

    #[test]
    fn spans_measure_and_nest() {
        let _g = lock();
        drain();
        enable();
        {
            let _outer = span("test", "outer").arg("k", 3.0);
            let _inner = span("test", "inner");
            std::hint::black_box(0u64);
        }
        disable();
        let evs = drain();
        assert_eq!(evs.len(), 2);
        // Spans flush at guard drop, so the inner span lands first.
        assert_eq!(evs[0].name, "inner");
        assert_eq!(evs[1].name, "outer");
        let (inner, outer) = (&evs[0], &evs[1]);
        assert_eq!(inner.tid, outer.tid);
        assert!(outer.ts_ns <= inner.ts_ns);
        assert!(inner.ts_ns + inner.dur_ns <= outer.ts_ns + outer.dur_ns);
        assert_eq!(outer.args[0], Some(("k", 3.0)));
    }

    #[test]
    fn instants_route_to_explicit_tracks() {
        let _g = lock();
        drain();
        enable();
        instant_on("test", "cache.spill", chip_track(3), "bytes", 4096.0);
        disable();
        let evs = drain();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].tid, chip_track(3));
        assert_eq!(evs[0].kind, EventKind::Instant);
        assert_eq!(evs[0].args[0], Some(("bytes", 4096.0)));
    }

    #[test]
    fn trace_json_round_trips_through_util_json() {
        let _g = lock();
        drain();
        enable();
        name_track(PID_HOST, chip_track(0), "chip 0");
        {
            let _t = span("test", "span \"quoted\"").arg("a", 1.5).arg("b", 2.0);
        }
        instant_on("test", "marker", chip_track(0), "bytes", 12.0);
        disable();
        let evs = drain();
        let doc = Json::parse(&trace_json(&evs)).expect("trace JSON must parse");
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
        let te = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        // Metadata events precede the recorded ones.
        assert!(te.len() >= evs.len() + 1);
        let span_ev = te
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("span \"quoted\""))
            .expect("span event present");
        assert_eq!(span_ev.get("ph").and_then(Json::as_str), Some("X"));
        assert!(span_ev.get("dur").and_then(Json::as_f64).is_some());
        let args = span_ev.get("args").expect("args object");
        assert_eq!(args.get("a").and_then(Json::as_f64), Some(1.5));
        assert_eq!(args.get("b").and_then(Json::as_f64), Some(2.0));
        let inst = te
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("marker"))
            .expect("instant event present");
        assert_eq!(inst.get("ph").and_then(Json::as_str), Some("i"));
        assert_eq!(inst.get("s").and_then(Json::as_str), Some("t"));
    }

    #[test]
    fn worker_thread_events_flush_on_exit() {
        let _g = lock();
        drain();
        enable();
        std::thread::scope(|s| {
            s.spawn(|| {
                let _t = span("test", "worker-span");
            });
        });
        disable();
        let evs = drain();
        assert_eq!(evs.len(), 1, "scoped worker must flush before join");
        assert_eq!(evs[0].name, "worker-span");
    }

    #[test]
    fn chip_tracks_cannot_collide_with_thread_tracks() {
        assert!(chip_track(0) > u32::MAX as u64);
        assert_eq!(chip_track(5) - chip_track(0), 5);
    }

    #[test]
    fn node_tracks_sit_above_chip_tracks() {
        assert!(node_track(0) > chip_track(u32::MAX as usize));
        assert_eq!(node_track(3) - node_track(0), 3);
    }
}
