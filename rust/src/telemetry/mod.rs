//! Cycle-attribution telemetry: tracing spans, counters, and Perfetto
//! timelines.
//!
//! The paper's headline numbers are *attribution* claims — they come from
//! knowing exactly where cycles go (FFT stages vs scan recurrence vs
//! reconfiguration vs DRAM round-trips). This module is the measurement
//! half of that discipline for the host stack:
//!
//! * [`trace`] — a lock-cheap span/event recorder. Call sites pay one
//!   relaxed atomic load when tracing is disabled (no clock read, no
//!   allocation); when enabled, events accumulate in thread-local buffers
//!   and flush to a global sink in batches. [`trace::drain`] returns the
//!   recorded events and [`trace::trace_json`] serializes them as Chrome
//!   trace-event JSON, loadable directly in Perfetto (`ui.perfetto.dev`).
//! * [`counters`] — a process-wide registry of named monotonic counters
//!   (always on; one relaxed `fetch_add` per increment) with text and JSON
//!   snapshot exporters backing the CLI's `--metrics` flag.
//!
//! **Overhead contract.** Instrumentation must stay under 1% of hot-path
//! time with tracing disabled — the paper's own "<1% profiling overhead"
//! bar. `benches/observe.rs` measures the disabled-mode cost per call site
//! against the PR-4 hot-path kernels and fails CI (`BENCH_observe.json`
//! gate) if the bound is exceeded.
//!
//! **Track layout.** Host spans land on the recording thread's own track
//! (`pid` [`PID_HOST`], one `tid` per OS thread, named after the thread).
//! Per-chip state — cache spills/restores, carry and transpose exchange
//! markers — is emitted as *instant* events on dedicated chip tracks
//! ([`chip_track`]), because several batches for one chip can execute
//! concurrently on different workers and duration spans on a shared chip
//! track would overlap non-nestedly. Modeled PCU pipeline timelines
//! ([`crate::pcusim::stage_timeline`]) use their own process
//! ([`PID_PCUSIM`]) where one trace microsecond renders one modeled cycle.

pub mod counters;
pub mod trace;

pub use counters::{counter, metrics_json, snapshot, snapshot_text};
pub use trace::{
    chip_track, disable, drain, enable, enabled, instant, instant_arg, instant_on, name_track,
    node_track, span, trace_json, write_trace, EventKind, SpanGuard, TraceEvent, PID_HOST,
    PID_PCUSIM,
};
