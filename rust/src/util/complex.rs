//! Minimal complex arithmetic for the FFT substrate (`num-complex` is not
//! vendored in the offline image).

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Complex number over `f64`. `repr(C)` pins the `[re, im]` memory layout
/// the FFT SIMD butterflies (`crate::fft::simd`) load vectors from.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct C64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl C64 {
    /// Construct from real and imaginary parts.
    pub const fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// The additive identity.
    pub const ZERO: C64 = C64::new(0.0, 0.0);
    /// The multiplicative identity.
    pub const ONE: C64 = C64::new(1.0, 0.0);

    /// Purely real value.
    pub const fn real(re: f64) -> Self {
        Self { re, im: 0.0 }
    }

    /// `e^{iθ}` on the unit circle.
    pub fn cis(theta: f64) -> Self {
        Self::new(theta.cos(), theta.sin())
    }

    /// Complex conjugate.
    pub fn conj(self) -> Self {
        Self::new(self.re, -self.im)
    }

    /// Magnitude `|z|`.
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    /// Scale by a real factor.
    pub fn scale(self, s: f64) -> Self {
        Self::new(self.re * s, self.im * s)
    }
}

impl Add for C64 {
    type Output = C64;
    fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
}

impl AddAssign for C64 {
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for C64 {
    type Output = C64;
    fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for C64 {
    type Output = C64;
    fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Neg for C64 {
    type Output = C64;
    fn neg(self) -> C64 {
        C64::new(-self.re, -self.im)
    }
}

/// Max elementwise |a - b| over complex slices.
pub fn max_abs_diff_c(a: &[C64], b: &[C64]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff_c: length mismatch");
    a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_matches_hand_computation() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        let c = a * b; // (3+2) + i(-1+6)
        assert_eq!(c, C64::new(5.0, 5.0));
    }

    #[test]
    fn cis_unit_magnitude() {
        for k in 0..16 {
            let z = C64::cis(k as f64 * 0.7);
            assert!((z.abs() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn conj_negates_im() {
        assert_eq!(C64::new(1.0, 2.0).conj(), C64::new(1.0, -2.0));
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = C64::new(0.5, -0.25);
        let b = C64::new(-2.0, 4.0);
        let r = a + b - b;
        assert!((r - a).abs() < 1e-15);
    }

    #[test]
    fn scale_and_neg() {
        assert_eq!(C64::new(1.0, -2.0).scale(2.0), C64::new(2.0, -4.0));
        assert_eq!(-C64::new(1.0, -2.0), C64::new(-1.0, 2.0));
    }
}
