//! Plain-text table rendering for benchmark and report output.
//!
//! Every figure/table bench prints its rows through this module so the output
//! lines up with the paper's tables for eyeball comparison.

/// A simple left/right-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "table `{}`: row width {} != header width {}",
            self.title,
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of string slices.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render to a string. First column is left-aligned, the rest right-aligned.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let total: usize = widths.iter().sum::<usize>() + 3 * (ncols - 1);
            "-".repeat(total)
        };
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    if i == 0 {
                        format!("{:<w$}", c, w = widths[i])
                    } else {
                        format!("{:>w$}", c, w = widths[i])
                    }
                })
                .collect::<Vec<_>>()
                .join(" | ")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new("demo", &["design", "latency", "speedup"]);
        t.row_str(&["attention", "1.00 s", "1.00x"]);
        t.row_str(&["vector-fft", "4.59 ms", "217.74x"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("attention"));
        // Right-aligned numeric columns: speedup column ends aligned.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, sep, 2 rows
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        Table::new("t", &["a", "b"]).row_str(&["only-one"]);
    }

    #[test]
    fn unicode_width_counts_chars() {
        let mut t = Table::new("µ", &["col"]);
        t.row_str(&["1.0 µs"]);
        assert!(t.render().contains("µs"));
    }
}
