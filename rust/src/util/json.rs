//! Minimal JSON parser for the artifact manifest (`serde_json` is not
//! vendored in the offline image). Supports the full JSON value grammar
//! minus exotic number forms; plenty for `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer content (lossless for |n| < 2^53).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    /// Array content.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object content.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> JsonError {
        JsonError { offset: self.i, message: m.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "seq_len": 2048, "d_model": 32, "batch": 4, "dtype": "f32",
            "models": {
                "hyena": {"path": "hyena.hlo.txt", "input_shape": [4, 2048, 32]}
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("seq_len").unwrap().as_usize(), Some(2048));
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
        let hy = j.get("models").unwrap().get("hyena").unwrap();
        let shape: Vec<usize> =
            hy.get("input_shape").unwrap().as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![4, 2048, 32]);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap().as_str(),
            Some("a\nbA")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_usize(), Some(3));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
    }
}
