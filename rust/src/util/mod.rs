//! Offline-friendly infrastructure: PRNG, complex arithmetic, property-test
//! runner, CLI parsing and table formatting.
//!
//! The build image vendors only the `xla` crate's dependency closure, so the
//! usual ecosystem crates (`rand`, `proptest`, `clap`, `prettytable`) are not
//! available; these modules provide the small slices of them this crate needs.

pub mod cli;
pub mod complex;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;

pub use complex::C64;
pub use rng::XorShift;

/// Relative-error comparison for floating point model outputs.
///
/// Returns `true` when `a` and `b` agree to within `rel` relative error
/// (measured against the larger magnitude) or within `abs` absolute error.
pub fn approx_eq(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    if diff <= abs {
        return true;
    }
    let scale = a.abs().max(b.abs());
    diff <= rel * scale
}

/// Maximum absolute elementwise difference between two slices.
///
/// Panics if lengths differ — callers compare tensors of identical shape.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Format a quantity in engineering units (k / M / G / T / P).
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    let (scale, suffix) = if ax >= 1e15 {
        (1e15, "P")
    } else if ax >= 1e12 {
        (1e12, "T")
    } else if ax >= 1e9 {
        (1e9, "G")
    } else if ax >= 1e6 {
        (1e6, "M")
    } else if ax >= 1e3 {
        (1e3, "k")
    } else {
        (1.0, "")
    };
    format!("{:.3}{}", x / scale, suffix)
}

/// Format seconds with an adaptive unit (s / ms / µs / ns).
pub fn fmt_time(seconds: f64) -> String {
    let a = seconds.abs();
    if a >= 1.0 {
        format!("{seconds:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_exact() {
        assert!(approx_eq(1.0, 1.0, 0.0, 0.0));
    }

    #[test]
    fn approx_eq_rel_band() {
        assert!(approx_eq(100.0, 100.9, 0.01, 0.0));
        assert!(!approx_eq(100.0, 102.0, 0.01, 0.0));
    }

    #[test]
    fn approx_eq_abs_band() {
        assert!(approx_eq(1e-12, 0.0, 0.0, 1e-9));
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
    }

    #[test]
    #[should_panic]
    fn max_abs_diff_len_mismatch_panics() {
        max_abs_diff(&[1.0], &[1.0, 2.0]);
    }

    #[test]
    fn eng_units() {
        assert_eq!(eng(640e12), "640.000T");
        assert_eq!(eng(1.5e3), "1.500k");
        assert_eq!(eng(12.0), "12.000");
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(2e-3), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }
}
