//! Tiny CLI argument parser (the `clap` crate is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments,
//! which is all the `ssm-rdu` binary and the examples need.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order of appearance.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; bare `--flag` maps to `"true"`.
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another option.
                    let takes_value =
                        matches!(it.peek(), Some(n) if !n.starts_with("--"));
                    if takes_value {
                        out.options
                            .insert(stripped.to_string(), it.next().unwrap());
                    } else {
                        out.options.insert(stripped.to_string(), "true".into());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Look up an option by key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option as string with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Whether a bare flag (or any value) was supplied.
    pub fn flag(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    /// Parse an option as `usize` with a default. Panics with a clear message
    /// on malformed input (CLI boundary — fail fast).
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.get(key) {
            None => default,
            Some(v) => v
                .replace('_', "")
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: expected integer, got `{v}`")),
        }
    }

    /// Parse an option as `f64` with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: expected number, got `{v}`")),
        }
    }

    /// Parse a comma-separated list of `usize` (e.g. `--seq-lens 262144,524288`).
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().replace('_', "").parse().unwrap_or_else(|_| {
                        panic!("--{key}: expected comma-separated integers, got `{v}`")
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["map", "hyena", "--seq-len", "1024", "--verbose"]);
        assert_eq!(a.positional, vec!["map", "hyena"]);
        assert_eq!(a.get("seq-len"), Some("1024"));
        assert!(a.flag("verbose"));
        // A bare flag followed by a positional consumes it as a value —
        // documented greedy behaviour; use `--flag=true` to avoid it.
        let b = parse(&["--verbose", "hyena"]);
        assert_eq!(b.get("verbose"), Some("hyena"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--model=mamba", "--l=65536"]);
        assert_eq!(a.get("model"), Some("mamba"));
        assert_eq!(a.usize_or("l", 0), 65536);
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["--dry-run", "--out", "x.txt"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("out"), Some("x.txt"));
    }

    #[test]
    fn numeric_helpers_defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("x", 1.5), 1.5);
        assert_eq!(a.usize_list_or("ls", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn underscores_in_integers() {
        let a = parse(&["--l", "1_048_576"]);
        assert_eq!(a.usize_or("l", 0), 1 << 20);
    }

    #[test]
    fn usize_list_parses() {
        let a = parse(&["--ls", "262144, 524288,1048576"]);
        assert_eq!(a.usize_list_or("ls", &[]), vec![262144, 524288, 1048576]);
    }

    #[test]
    #[should_panic(expected = "expected integer")]
    fn malformed_integer_panics() {
        parse(&["--n", "abc"]).usize_or("n", 0);
    }
}
