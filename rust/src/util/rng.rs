//! Deterministic PRNG (xorshift64*) — the `rand` crate is not vendored in the
//! offline image, and deterministic seeds are what the tests want anyway.

/// A small, fast, seedable xorshift64* generator.
///
/// Statistical quality is far beyond what the tests and workload generators
/// here need; determinism (same seed → same stream on every platform) is the
/// property we rely on.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// requires non-zero state).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform `usize` in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "XorShift::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Vector of uniform values in `[lo, hi)`.
    pub fn vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }

    /// Standard-normalish value via the sum of 12 uniforms (Irwin–Hall);
    /// adequate for test data generation.
    pub fn normalish(&mut self) -> f64 {
        (0..12).map(|_| self.next_f64()).sum::<f64>() - 6.0
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = XorShift::new(9);
        for _ in 0..1000 {
            let x = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }

    #[test]
    fn below_and_range() {
        let mut r = XorShift::new(11);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(3, 6);
            assert!((3..=6).contains(&x));
        }
    }

    #[test]
    fn mean_is_roughly_centered() {
        let mut r = XorShift::new(13);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
