//! Mini property-testing runner — a deliberately small stand-in for
//! `proptest`, which is not vendored in the offline image.
//!
//! The runner draws `cases` random inputs from a generator, checks a property
//! returning `Result<(), String>`, and on failure performs greedy shrinking
//! using a caller-supplied shrink function before panicking with the minimal
//! counterexample. Deterministic: the seed is part of the call, so failures
//! reproduce exactly.

use super::rng::XorShift;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases to draw.
    pub cases: usize,
    /// RNG seed (failures reproduce with the same seed).
    pub seed: u64,
    /// Maximum shrink iterations on failure.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 128,
            seed: 0xD15EA5E,
            max_shrink: 512,
        }
    }
}

/// Run a property over random inputs with shrinking.
///
/// * `gen` — draws one random input.
/// * `shrink` — proposes strictly "smaller" candidates for a failing input
///   (return an empty vec when fully shrunk).
/// * `prop` — the property; `Err(msg)` marks a failure.
///
/// Panics with the minimal counterexample on failure.
pub fn check<T, G, S, P>(cfg: &Config, name: &str, mut gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut XorShift) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = XorShift::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first still-failing candidate.
            let mut cur = input;
            let mut msg = first_msg;
            let mut budget = cfg.max_shrink;
            'outer: while budget > 0 {
                for cand in shrink(&cur) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property `{name}` failed (case {case}, seed {seed:#x}):\n  \
                 counterexample: {cur:?}\n  error: {msg}",
                seed = cfg.seed
            );
        }
    }
}

/// Convenience: run a property with the default config.
pub fn quick<T, G, S, P>(name: &str, gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut XorShift) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    check(&Config::default(), name, gen, shrink, prop)
}

/// Shrinker for `usize`: halves and decrements.
pub fn shrink_usize(x: &usize) -> Vec<usize> {
    let mut out = Vec::new();
    if *x > 0 {
        out.push(x / 2);
        out.push(x - 1);
    }
    out.dedup();
    out
}

/// Shrinker for `Vec<f64>`: drop halves, zero elements, halve magnitudes.
#[allow(clippy::ptr_arg)] // shrinkers take &T where T = Vec<f64>
pub fn shrink_vec_f64(xs: &Vec<f64>) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n > 1 {
        out.push(xs[..n / 2].to_vec());
        out.push(xs[n / 2..].to_vec());
    }
    if xs.iter().any(|&x| x != 0.0) {
        out.push(xs.iter().map(|&x| x / 2.0).collect());
        out.push(vec![0.0; n]);
    }
    out
}

/// No-op shrinker for inputs where shrinking isn't meaningful.
pub fn no_shrink<T>(_: &T) -> Vec<T> {
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quick(
            "add-commutes",
            |r| (r.uniform(-1e3, 1e3), r.uniform(-1e3, 1e3)),
            no_shrink,
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("not commutative".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        // Property "x < 10" fails; shrinking should land on exactly 10.
        let result = std::panic::catch_unwind(|| {
            check(
                &Config { cases: 64, seed: 3, max_shrink: 256 },
                "lt-ten",
                |r| r.range(10, 1000),
                |x| shrink_usize(x).into_iter().filter(|&c| c >= 10).collect(),
                |&x| if x < 10 { Ok(()) } else { Err(format!("{x} >= 10")) },
            );
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().expect("panic message");
        assert!(msg.contains("counterexample: 10"), "msg: {msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        // Two identical failing runs produce identical messages.
        let run = || {
            std::panic::catch_unwind(|| {
                check(
                    &Config { cases: 16, seed: 77, max_shrink: 8 },
                    "always-fails",
                    |r| r.below(100),
                    no_shrink,
                    |&x| Err(format!("x={x}")),
                )
            })
            .expect_err("fails")
            .downcast_ref::<String>()
            .unwrap()
            .clone()
        };
        assert_eq!(run(), run());
    }
}
