//! Chip-level power and per-inference energy model — the natural corollary
//! of Table IV: the extensions add ≤ 0.5 % chip power while cutting
//! latency by 2–5×, so *energy per inference* drops almost proportionally
//! to the speedup.
//!
//! Chip power = Σ PCU power (per-variant, from the Table IV model, scaled
//! to the production 32×12 geometry) + PMU SRAM power + HBM interface
//! power. Energy(workload) = chip power × modeled latency (+ DRAM transfer
//! energy at pJ/bit).

use super::{baseline_power, synthesize};
use crate::arch::{PcuMode, RduConfig};
use crate::dfmodel::Estimate;

/// PMU (1.5 MB SRAM + address generators) power at 1.6 GHz, mW.
/// Literature-scale figure for a 45 nm 1.5 MB SRAM macro under activity.
pub const PMU_POWER_MW: f64 = 95.0;

/// HBM interface energy, pJ per bit transferred.
pub const HBM_PJ_PER_BIT: f64 = 3.5;

/// Chip-level power breakdown in watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChipPower {
    pub pcu_w: f64,
    pub pmu_w: f64,
    pub total_w: f64,
}

/// Static+dynamic chip power of an RDU configuration (compute + SRAM).
pub fn chip_power(cfg: &RduConfig) -> ChipPower {
    let geom = cfg.spec.pcu;
    // Per-PCU power: baseline plus every fabricated extension's routes.
    let mut pcu_mw = baseline_power(geom);
    for &mode in &cfg.extensions {
        let s = synthesize(geom, Some(mode));
        pcu_mw += s.power_mw - baseline_power(geom);
    }
    let pcu_w = pcu_mw * cfg.spec.n_pcu as f64 / 1e3;
    let pmu_w = PMU_POWER_MW * cfg.spec.n_pmu as f64 / 1e3;
    ChipPower { pcu_w, pmu_w, total_w: pcu_w + pmu_w }
}

/// Energy (joules) to run one workload whose DFModel estimate is `est` on
/// configuration `cfg`: chip power × latency + DRAM transfer energy.
pub fn inference_energy(cfg: &RduConfig, est: &Estimate, dram_bytes: f64) -> f64 {
    let p = chip_power(cfg);
    p.total_w * est.total_seconds + dram_bytes * 8.0 * HBM_PJ_PER_BIT * 1e-12
}

/// Energy overhead ratio of fabricating `mode` into every PCU, chip-wide —
/// Table IV's < 1 % claim expressed at chip scale.
pub fn extension_power_overhead(mode: PcuMode) -> f64 {
    let base = RduConfig::baseline();
    let ext = RduConfig::baseline().with_extension(mode);
    chip_power(&ext).total_w / chip_power(&base).total_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfmodel;
    use crate::fft::BaileyVariant;
    use crate::workloads::{hyena_decoder, DecoderConfig};

    #[test]
    fn chip_power_plausible() {
        // 520 production PCUs + 520 PMUs in 45 nm-scale figures: hundreds
        // of watts (accelerator-class), not milliwatts or megawatts.
        let p = chip_power(&RduConfig::baseline());
        assert!(p.total_w > 100.0 && p.total_w < 2000.0, "{p:?}");
    }

    #[test]
    fn extension_power_under_one_percent_chipwide() {
        for mode in [PcuMode::Fft, PcuMode::HsScan, PcuMode::BScan] {
            let r = extension_power_overhead(mode);
            assert!(r > 1.0 && r < 1.01, "{mode}: {r}");
        }
    }

    #[test]
    fn fft_mode_cuts_energy_per_inference() {
        // The paper's implicit energy story: ~0.3 % more power, ~4× less
        // time → ~4× less energy per inference.
        let dc = DecoderConfig::paper(1 << 20);
        let g = hyena_decoder(&dc, BaileyVariant::Vector);
        let base = RduConfig::baseline();
        let fftm = RduConfig::fft_mode();
        let io = g.external_input_bytes() + g.external_output_bytes() + g.total_weight_bytes();
        let e_base = inference_energy(&base, &dfmodel::estimate(&g, &base).unwrap(), io);
        let e_fft = inference_energy(&fftm, &dfmodel::estimate(&g, &fftm).unwrap(), io);
        let gain = e_base / e_fft;
        assert!(gain > 2.0, "energy gain {gain}");
    }

    #[test]
    fn dram_energy_counts() {
        let cfg = RduConfig::baseline();
        let est = Estimate {
            graph_name: "x".into(),
            cfg_name: cfg.name(),
            total_seconds: 0.0,
            compute_seconds: 0.0,
            memory_seconds: 0.0,
            reconfig_seconds: 0.0,
            sections: 1,
            kernels: vec![],
        };
        let e = inference_energy(&cfg, &est, 1e9);
        assert!((e - 1e9 * 8.0 * HBM_PJ_PER_BIT * 1e-12).abs() < 1e-12);
    }
}
