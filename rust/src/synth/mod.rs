//! 45 nm synthesis area/power model of the PCU variants (paper §V,
//! Table IV).
//!
//! The paper implements the baseline and the three enhanced PCUs (8×6
//! arrays, SInt16) in Chisel, synthesizes with Design Compiler on TSMC
//! 45 nm at 1.6 GHz, and reports < 1 % overheads. We reproduce the study
//! with a component-level model:
//!
//! * the **baseline PCU** is a netlist of per-FU datapath components
//!   (multiplier, adder, operand registers, input-select muxing, config
//!   bits) plus array-level overhead (FIFOs, counters, control). Literature
//!   TSMC-45 nm cell areas are used for the component mix and the totals
//!   are anchored to the paper's synthesized baseline (90899.1 µm²,
//!   140.7 mW) — the anchor is the one CALIBRATED quantity;
//! * each **extension** adds one W-bit 2:1 input mux + one W-bit lane route
//!   per cross-lane route counted by
//!   [`crate::pcusim::topology::added_mux_count`] — 24 (FFT), 17 (HS),
//!   14 (B-scan) on the 8×6 array. The per-route cost (mux cells + wire
//!   load) is calibrated once against the FFT-mode delta and *reused* for
//!   the scan modes, so the HS/B rows are genuine predictions.
//!
//! Table IV reproduction with these two calibrations:
//!
//! | PCU      | paper area (×)    | model area (×)    | paper mW (×)   |
//! |----------|-------------------|-------------------|----------------|
//! | baseline | 90899.1 (1×)      | 90899.1 (1×)      | 140.7 (1×)     |
//! | FFT      | 91572.9 (1.007×)  | 91572.9 (1.007×)  | 141.4 (1.005×) |
//! | HS-scan  | 91383.0 (1.005×)  | 91376.4 (1.005×)  | 141.2 (1.004×) |
//! | B-scan   | 91275.7 (1.004×)  | 91292.2 (1.004×)  | 141.1 (1.003×) |

pub mod energy;

use crate::arch::{PcuGeometry, PcuMode};
use crate::pcusim::topology;
use crate::util::table::Table;

/// Datapath word width the paper synthesizes (SInt16 — "due to Chisel's
/// limited support for floating-point arithmetic", §V).
pub const WORD_BITS: usize = 16;

/// TSMC 45 nm component areas in µm² (literature-scale relative values;
/// the absolute scale is anchored below).
pub mod cells {
    /// 16×16-bit signed multiplier.
    pub const MULT16_UM2: f64 = 1085.0;
    /// 16-bit adder.
    pub const ADD16_UM2: f64 = 170.0;
    /// 16-bit register (operand + pipeline).
    pub const REG16_UM2: f64 = 96.0;
    /// 16-bit 2:1 mux.
    pub const MUX2_16_UM2: f64 = 24.0;
    /// Per-FU configuration/control bits.
    pub const FU_CFG_UM2: f64 = 55.0;
}

/// Paper Table IV anchors (the CALIBRATED quantities).
pub mod anchor {
    /// Synthesized baseline 8×6 PCU area (Table IV).
    pub const BASELINE_AREA_UM2: f64 = 90_899.1;
    /// Synthesized baseline 8×6 PCU power at 1.6 GHz (Table IV).
    pub const BASELINE_POWER_MW: f64 = 140.7;
    /// Per-route added cost, calibrated from the FFT-mode delta:
    /// (91572.9 − 90899.1) / 24 routes = 28.075 µm² (mux + wire load).
    pub const ROUTE_AREA_UM2: f64 = (91_572.9 - BASELINE_AREA_UM2) / 24.0;
    /// Per-route power, likewise: (141.4 − 140.7) / 24 ≈ 0.0292 mW.
    pub const ROUTE_POWER_MW: f64 = (141.4 - BASELINE_POWER_MW) / 24.0;
}

/// Synthesis result for one PCU variant.
#[derive(Debug, Clone, PartialEq)]
pub struct PcuSynthesis {
    /// `None` = baseline; `Some(mode)` = extended PCU.
    pub mode: Option<PcuMode>,
    pub geom: PcuGeometry,
    pub area_um2: f64,
    pub power_mw: f64,
    /// Cross-lane routes the extension added.
    pub added_routes: usize,
}

impl PcuSynthesis {
    /// Area overhead relative to the baseline of the same geometry.
    pub fn area_ratio(&self) -> f64 {
        self.area_um2 / baseline_area(self.geom)
    }

    /// Power overhead relative to the baseline of the same geometry.
    pub fn power_ratio(&self) -> f64 {
        self.power_mw / baseline_power(self.geom)
    }
}

/// Component-mix area of the baseline PCU *before* anchoring: per-FU
/// datapath plus array overhead growing with lanes (FIFOs) and stages
/// (control).
fn raw_component_area(geom: PcuGeometry) -> f64 {
    use cells::*;
    let per_fu = MULT16_UM2 + ADD16_UM2 + 2.0 * REG16_UM2 + 3.0 * MUX2_16_UM2 + FU_CFG_UM2;
    let fu_total = geom.fu_count() as f64 * per_fu;
    // Array-level overhead: input/output FIFOs per lane, per-stage control.
    let fifo = geom.lanes as f64 * 2.0 * 8.0 * REG16_UM2;
    let control = geom.stages as f64 * 180.0;
    fu_total + fifo + control
}

/// Baseline PCU area for any geometry, anchored so the paper's 8×6 PCU
/// synthesizes to exactly Table IV's 90899.1 µm².
pub fn baseline_area(geom: PcuGeometry) -> f64 {
    let anchor_geom = PcuGeometry::synthesis();
    anchor::BASELINE_AREA_UM2 * raw_component_area(geom) / raw_component_area(anchor_geom)
}

/// Baseline PCU power (mW at 1.6 GHz), scaled with active area.
pub fn baseline_power(geom: PcuGeometry) -> f64 {
    anchor::BASELINE_POWER_MW * baseline_area(geom) / anchor::BASELINE_AREA_UM2
}

/// Synthesize one PCU variant on `geom`. `mode = None` gives the baseline.
pub fn synthesize(geom: PcuGeometry, mode: Option<PcuMode>) -> PcuSynthesis {
    let routes = mode.map(|m| topology::added_mux_count(m, geom)).unwrap_or(0);
    let area = baseline_area(geom) + routes as f64 * anchor::ROUTE_AREA_UM2;
    let power = baseline_power(geom) + routes as f64 * anchor::ROUTE_POWER_MW;
    PcuSynthesis { mode, geom, area_um2: area, power_mw: power, added_routes: routes }
}

/// The four Table IV rows on the paper's 8×6 synthesis geometry.
pub fn table4_rows() -> Vec<PcuSynthesis> {
    let geom = PcuGeometry::synthesis();
    vec![
        synthesize(geom, None),
        synthesize(geom, Some(PcuMode::Fft)),
        synthesize(geom, Some(PcuMode::HsScan)),
        synthesize(geom, Some(PcuMode::BScan)),
    ]
}

/// Render Table IV with paper-vs-model columns.
pub fn table4_report() -> Table {
    let paper: [(&str, f64, f64); 4] = [
        ("Baseline PCU", 90_899.1, 140.7),
        ("FFT-Mode PCU", 91_572.9, 141.4),
        ("HS-Scan PCU", 91_383.0, 141.2),
        ("B-Scan PCU", 91_275.7, 141.1),
    ];
    let mut t = Table::new(
        "TABLE IV — area and power overheads of the enhanced PCUs",
        &["PCU", "Area µm² (model)", "×", "Power mW (model)", "×", "Area µm² (paper)", "Power mW (paper)"],
    );
    for (row, (name, pa, pp)) in table4_rows().iter().zip(paper) {
        t.row(&[
            name.to_string(),
            format!("{:.1}", row.area_um2),
            format!("{:.3}x", row.area_ratio()),
            format!("{:.1}", row.power_mw),
            format!("{:.3}x", row.power_ratio()),
            format!("{pa:.1}"),
            format!("{pp:.1}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_anchored_exactly() {
        let b = synthesize(PcuGeometry::synthesis(), None);
        assert!((b.area_um2 - 90_899.1).abs() < 1e-9);
        assert!((b.power_mw - 140.7).abs() < 1e-9);
        assert_eq!(b.added_routes, 0);
    }

    #[test]
    fn fft_mode_matches_paper_exactly() {
        // The FFT row is the calibration point — must be exact.
        let f = synthesize(PcuGeometry::synthesis(), Some(PcuMode::Fft));
        assert!((f.area_um2 - 91_572.9).abs() < 1e-6, "area={}", f.area_um2);
        assert!((f.power_mw - 141.4).abs() < 1e-6);
    }

    #[test]
    fn hs_scan_predicted_within_tenth_percent() {
        // HS/B rows are predictions from the route counts; the paper's
        // synthesized values land within 0.1 % of the model.
        let h = synthesize(PcuGeometry::synthesis(), Some(PcuMode::HsScan));
        assert!((h.area_um2 - 91_383.0).abs() / 91_383.0 < 1e-3, "area={}", h.area_um2);
        assert!((h.power_mw - 141.2).abs() / 141.2 < 1e-3, "power={}", h.power_mw);
    }

    #[test]
    fn b_scan_predicted_within_tenth_percent() {
        let b = synthesize(PcuGeometry::synthesis(), Some(PcuMode::BScan));
        assert!((b.area_um2 - 91_275.7).abs() / 91_275.7 < 1e-3, "area={}", b.area_um2);
        assert!((b.power_mw - 141.1).abs() / 141.1 < 1e-3, "power={}", b.power_mw);
    }

    #[test]
    fn all_overheads_below_one_percent() {
        // The paper's headline: every extension costs < 1 % area and power.
        for row in table4_rows() {
            assert!(row.area_ratio() < 1.01, "{:?}: {}", row.mode, row.area_ratio());
            assert!(row.power_ratio() < 1.01, "{:?}: {}", row.mode, row.power_ratio());
        }
    }

    #[test]
    fn overhead_ordering_fft_hs_b() {
        // Table IV ordering: FFT > HS > B.
        let r = table4_rows();
        assert!(r[1].area_um2 > r[2].area_um2);
        assert!(r[2].area_um2 > r[3].area_um2);
        assert!(r[1].power_mw >= r[2].power_mw && r[2].power_mw >= r[3].power_mw);
    }

    #[test]
    fn production_pcu_still_under_one_percent() {
        // The 32×12 production PCU: 160 routes on a 8× bigger datapath —
        // overheads stay ~1 %.
        let geom = PcuGeometry::table1();
        let f = synthesize(geom, Some(PcuMode::Fft));
        assert!(f.area_ratio() < 1.01, "ratio={}", f.area_ratio());
        assert!(f.area_ratio() > 1.001);
    }

    #[test]
    fn area_scales_with_geometry() {
        let small = baseline_area(PcuGeometry::synthesis());
        let big = baseline_area(PcuGeometry::table1());
        // 48 → 384 FUs: ~8× datapath, sublinear overhead terms.
        let r = big / small;
        assert!(r > 6.0 && r < 9.0, "r={r}");
    }

    #[test]
    fn table4_report_renders() {
        let s = table4_report().render();
        assert!(s.contains("90899.1"));
        assert!(s.contains("1.007x"), "{s}");
    }
}
