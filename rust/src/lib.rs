//! # SSM-RDU — Reconfigurable Dataflow Unit for Long-Sequence State-Space Models
//!
//! Full-system reproduction of *"SSM-RDU: A Reconfigurable Dataflow Unit for
//! Long-Sequence State-Space Models"* (Sho Ko, CS.AR 2025).
//!
//! The paper proposes lightweight cross-lane interconnect extensions to the
//! Pattern Compute Units (PCUs) of a Reconfigurable Dataflow Unit (RDU) so that
//! FFT-based (Hyena) and scan-based (Mamba) state-space models map spatially
//! onto the fabric. This crate rebuilds the paper's entire evaluation stack:
//!
//! * [`arch`] — the RDU chip description (Table I) and platform abstractions.
//! * [`pcusim`] — a cycle-level functional simulator of a PCU in every mode
//!   (element-wise / systolic / reduction / FFT / HS-scan / B-scan); numerics
//!   checked against the algorithm substrates, utilization feeds the perf
//!   model. Programs are authored with the
//!   [`define_pcu_program!`](crate::define_pcu_program) DSL (named stages,
//!   folded constants, routes checked at construction) and can be
//!   single-stepped in the [`pcusim::debug`] debugger — breakpoints,
//!   register/NoC snapshots, deterministic resume (`debug` subcommand).
//! * [`fft`], [`scan`] — the algorithm substrates (Cooley–Tukey, Bailey 4-step
//!   Vector/GEMM variants, C-scan, Hillis–Steele, Blelloch, tiled scan).
//! * [`graph`], [`workloads`] — dataflow-graph IR, the decoder builders
//!   (attention / Hyena / Mamba, paper Fig. 3, plus Mamba-2 SSD and S4
//!   long-conv) and the **workload registry**
//!   ([`mod@workloads::registry`]): one trait per SSM variant — graph, decode
//!   demand, shard pattern, golden model — that `simulate`/`serve`/
//!   `sweep`/`bench` resolve by name (`--workload`); adding a variant is
//!   one module + one registry line (`docs/WORKLOADS.md`).
//! * [`dfmodel`] — reproduction of the DFModel mapping optimizer + performance
//!   estimator used for every figure in the paper, plus the fusion pass
//!   (`dfmodel::fusion`) that clusters streamed kernel chains into single
//!   spatially-mapped sections and the launch-granularity estimates that
//!   price the fused-vs-unfused gap (`simulate --fuse`, the `fusion` bench).
//! * [`gpu`], [`vga`] — the A100 and VGA comparison platforms (Tables II/III).
//! * [`synth`] — 45 nm area/power model reproducing Table IV.
//! * [`runtime`], [`coordinator`] — the serving stack: PJRT artifact execution
//!   plus a request router / dynamic batcher, so the decoder layers built in
//!   JAX/Pallas (L1/L2) actually run end-to-end under the Rust leader (L3).
//! * [`session`] — per-sequence SSM decode state (Mamba recurrent blocks,
//!   Hyena FFT caches) under a byte-budgeted LRU cache, plus the
//!   continuous-batching scheduler that serves multi-turn/streaming decode
//!   (`serve --continuous`).
//! * [`fleet`] — the multi-node serving tier: a placement router over N
//!   simulated nodes, live session migration (checkpoint → transfer →
//!   resume over the α–β link), drain/fail-stop scenarios with lossless
//!   recovery, and trace-driven load generation with an SLO report
//!   (the `fleet` subcommand, `docs/FLEET.md`).
//! * [`shard`] — multi-chip sequence sharding: exact sharded Mamba scan
//!   (inter-chip carry exchange) and sharded Bailey FFT (all-to-all
//!   transpose), priced end-to-end through [`arch::interchip`] and the
//!   sharded DFModel estimates (`--chips`, the `shard_scaling` bench).
//! * [`telemetry`] — cycle-attribution observability: a zero-overhead-when-
//!   disabled span recorder emitting Perfetto-loadable Chrome trace JSON
//!   (per-thread and per-chip tracks), plus a counter registry with
//!   text/JSON snapshots (`--trace`/`--metrics`, the `observe` bench gate).
//! * [`util`], [`bench`] — offline-friendly infrastructure (PRNG, mini
//!   property-test runner, CLI parsing, bench harness).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results on every table and figure.

pub mod arch;
pub mod bench;
pub mod coordinator;
pub mod dfmodel;
pub mod fft;
pub mod figures;
pub mod fleet;
pub mod gpu;
pub mod graph;
pub mod pcusim;
pub mod runtime;
pub mod scan;
pub mod session;
pub mod shard;
pub mod synth;
pub mod telemetry;
pub mod util;
pub mod vga;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
