//! Per-kernel effective throughput on an RDU configuration — the link
//! between the cycle-level PCU simulator and the performance estimator.
//!
//! For each [`OpClass`] the model derives how fast one PCU retires the
//! kernel's work, *measured* from [`crate::pcusim::utilization`] rather than
//! hand-entered:
//!
//! | op class      | baseline RDU                 | extended RDU              |
//! |---------------|------------------------------|---------------------------|
//! | gemm/gemm-fft | systolic, full MAC rate      | (same)                    |
//! | vector-fft    | serialized: 1/stages of peak | spatial: levels/stages    |
//! | parallel scan | serialized: 1/stages of peak | spatial: levels/stages¹   |
//! | c-scan        | 1 element-update per cycle, chip-wide (inherently serial) |
//! | eltwise/softmax/norm | full lane rate (element-wise mode)              |
//!
//! ¹ measured on whichever scan fabric the config provides; the HS and B
//!   fabrics give identical *tile* throughput (one scan per cycle, §IV-C),
//!   which the flop-rate normalization below preserves.

use crate::arch::RduConfig;
use crate::graph::{Kernel, OpClass};
use crate::pcusim::utilization;

/// How one PCU retires a kernel's work.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rate {
    /// Effective FLOP/s per PCU; the kernel divides freely across PCUs.
    FlopsPerPcu(f64),
    /// The kernel is inherently sequential: fixed time in seconds,
    /// independent of how many PCUs are allocated (paper §IV-A on C-scan).
    SerialSeconds(f64),
}

/// Effective per-PCU throughput for `kernel` on `cfg`.
pub fn kernel_rate(kernel: &Kernel, cfg: &RduConfig) -> Rate {
    let spec = &cfg.spec;
    let pcu_peak = spec.pcu.peak_flops(spec.clock_hz);
    match kernel.op {
        // Systolic mode sustains a MAC in every FU (paper Fig. 2); the
        // GEMM-FFT variant exists precisely because it reaches this rate.
        OpClass::Gemm | OpClass::GemmFft => Rate::FlopsPerPcu(pcu_peak),

        // Vector FFT: pipeline factor measured on the cycle-level engine —
        // 1/stages serialized on the baseline (paper §III-B: "only the
        // first stage of the pipeline"), levels/stages spatial on the
        // FFT-mode PCU.
        OpClass::VectorFft => {
            let m = utilization::vector_fft(cfg);
            Rate::FlopsPerPcu(pcu_peak * m.pipeline_factor)
        }

        // Parallel scan: the fabric's *tile rate* is what matters — both the
        // HS and B fabrics retire one `lanes`-element scan per cycle
        // (paper §IV-C: "each mode supports a throughput of one scan per
        // cycle"), so their effective rates are identical even though their
        // stage occupancies differ. Serialized on the baseline, the tile
        // rate drops by the level count (II = levels).
        OpClass::ScanParallel => {
            let m = utilization::parallel_scan(cfg);
            let lanes = spec.pcu.lanes as f64;
            let updates_per_sec = lanes * spec.clock_hz / m.initiation_interval;
            let updates = kernel.elements * kernel.channels;
            if updates > 0.0 {
                // Normalize the kernel's own FLOP accounting to its update
                // count so the rate is tile-throughput-faithful.
                Rate::FlopsPerPcu(kernel.flops / updates * updates_per_sec)
            } else {
                // No stream metadata: assume the Blelloch-lift accounting
                // (6 FLOP per element-update, see workloads::mamba).
                Rate::FlopsPerPcu(6.0 * updates_per_sec)
            }
        }

        // C-scan: "inherently sequential, computing each output element one
        // at a time" (§IV-A) — one element-update (2 FLOP) per cycle no
        // matter how much hardware is thrown at it.
        OpClass::ScanSerial => {
            let updates = kernel.elements * kernel.channels;
            Rate::SerialSeconds(updates / spec.clock_hz)
        }

        // Vector-path kernels run in element-wise mode: every lane busy,
        // one op per FU per cycle, i.e. half the MAC peak.
        OpClass::Elementwise | OpClass::Softmax | OpClass::Norm => {
            Rate::FlopsPerPcu(pcu_peak / 2.0)
        }
    }
}

/// Cycles to reconfigure the fabric between spatial-program launches: the
/// per-section cost of loading PCU configurations, retargeting PMU address
/// generators and refilling the pipelines. RDU-class machines switch
/// configurations in microseconds, not milliseconds — 10k cycles is 6.25 µs
/// at the Table I clock. The launch-granularity estimates
/// ([`super::perf::estimate_fused`] / [`super::perf::estimate_unfused`])
/// charge this once per section, which is precisely what fusion amortizes:
/// a fused FFT→eltwise→iFFT chain is one launch where kernel-by-kernel
/// execution pays four.
pub const RECONFIG_CYCLES: f64 = 10_000.0;

/// Seconds per fabric reconfiguration on `cfg` (see [`RECONFIG_CYCLES`]).
pub fn reconfig_seconds(cfg: &RduConfig) -> f64 {
    RECONFIG_CYCLES / cfg.spec.clock_hz
}

/// Time for one PCU to retire the kernel (the mapper's demand metric).
pub fn pcu_seconds(kernel: &Kernel, cfg: &RduConfig) -> f64 {
    match kernel_rate(kernel, cfg) {
        Rate::FlopsPerPcu(r) => kernel.flops / r,
        Rate::SerialSeconds(t) => t,
    }
}

/// Is the kernel's time independent of PCU allocation?
pub fn is_serial(kernel: &Kernel) -> bool {
    matches!(kernel.op, OpClass::ScanSerial)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Kernel;

    fn k(op: OpClass, flops: f64) -> Kernel {
        Kernel::new("k", op, flops, 1.0, 1.0)
    }

    #[test]
    fn gemm_runs_at_peak() {
        let cfg = RduConfig::baseline();
        let peak = cfg.spec.pcu.peak_flops(cfg.spec.clock_hz);
        match kernel_rate(&k(OpClass::Gemm, 1e9), &cfg) {
            Rate::FlopsPerPcu(r) => assert_eq!(r, peak),
            _ => panic!("gemm should be divisible"),
        }
    }

    #[test]
    fn vector_fft_12x_gap_between_configs() {
        let kern = k(OpClass::VectorFft, 1e12);
        let base = pcu_seconds(&kern, &RduConfig::baseline());
        let fft = pcu_seconds(&kern, &RduConfig::fft_mode());
        // baseline 1/12 vs fft-mode 5/12 → 5× faster per PCU.
        let ratio = base / fft;
        assert!((ratio - 5.0).abs() < 1e-9, "ratio={ratio}");
    }

    #[test]
    fn scan_levels_gap_between_configs() {
        // Serialized II = 5 levels vs spatial II = 1 → ~5× rate gap on the
        // 32-lane PCU (paper Fig. 11's Design 3 → 4 per-kernel gain).
        let kern = k(OpClass::ScanParallel, 1e12).with_stream(1e6, 32.0);
        let base = pcu_seconds(&kern, &RduConfig::baseline());
        let hs = pcu_seconds(&kern, &RduConfig::hs_scan_mode());
        let ratio = base / hs;
        assert!(ratio > 4.5 && ratio < 5.5, "ratio={ratio}");
    }

    #[test]
    fn hs_and_b_equal_rates() {
        // Paper §IV-C: HS-mode and B-mode deliver identical performance —
        // one scan tile per cycle on either fabric.
        let kern = k(OpClass::ScanParallel, 1e12).with_stream(1e6, 32.0);
        let hs = pcu_seconds(&kern, &RduConfig::hs_scan_mode());
        let b = pcu_seconds(&kern, &RduConfig::b_scan_mode());
        assert!((hs - b).abs() / hs < 0.01, "hs={hs} b={b}");
        // The metadata-free fallback path agrees too.
        let bare = k(OpClass::ScanParallel, 1e12);
        let hs2 = pcu_seconds(&bare, &RduConfig::hs_scan_mode());
        let b2 = pcu_seconds(&bare, &RduConfig::b_scan_mode());
        assert!((hs2 - b2).abs() / hs2 < 0.01, "hs2={hs2} b2={b2}");
    }

    #[test]
    fn c_scan_is_fixed_time() {
        let cfg = RduConfig::baseline();
        let kern = Kernel::new("scan", OpClass::ScanSerial, 2e6, 1.0, 1.0).with_stream(1e6, 1.0);
        match kernel_rate(&kern, &cfg) {
            Rate::SerialSeconds(t) => {
                // 1e6 updates at 1.6 GHz = 625 µs.
                assert!((t - 1e6 / 1.6e9).abs() < 1e-12);
            }
            _ => panic!("c-scan must be serial"),
        }
        assert!(is_serial(&kern));
    }

    #[test]
    fn reconfig_is_microseconds_at_table1_clock() {
        let t = reconfig_seconds(&RduConfig::baseline());
        assert!((t - 10_000.0 / 1.6e9).abs() < 1e-15);
        assert!(t > 1e-6 && t < 1e-4, "reconfig should be µs-scale, got {t}");
    }

    #[test]
    fn c_scan_unaffected_by_extensions() {
        let kern = Kernel::new("scan", OpClass::ScanSerial, 2e6, 1.0, 1.0).with_stream(1e6, 32.0);
        let a = pcu_seconds(&kern, &RduConfig::baseline());
        let b = pcu_seconds(&kern, &RduConfig::b_scan_mode());
        assert_eq!(a, b);
    }
}
