//! Design-space exploration: sweep chip parameters (PCU count, geometry,
//! memory bandwidth, Bailey tile size) and report how the paper's headline
//! results move — the ablation study DFModel (paper Fig. 4: "multi-level
//! optimization … design space optimization") was built for.
//!
//! Since the workload registry, every sweep is generic over
//! [`crate::workloads::Workload`]s: the CLI's `sweep --workload …` picks
//! any subset of the registered decoders, each priced on its own
//! [`Workload::extended_config`] design point with the gain measured
//! against the baseline chip under the same spec edit.

use super::perf::estimate;
use crate::arch::{MemTech, RduConfig};
use crate::util::fmt_time;
use crate::util::table::Table;
use crate::workloads::{DecoderConfig, Workload};

/// One workload's numbers at one swept design point.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadPoint {
    /// Registry name of the workload.
    pub workload: &'static str,
    /// Latency on the workload's extended configuration at this point.
    pub seconds: f64,
    /// Speedup of the extended configuration over the baseline configuration
    /// at this design point (1.0 when the workload needs no extension).
    pub gain: f64,
}

/// One swept design point: a label plus a row per swept workload.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub label: String,
    pub rows: Vec<WorkloadPoint>,
}

impl SweepPoint {
    /// This point's row for a workload, by registry name.
    pub fn row(&self, workload: &str) -> Option<&WorkloadPoint> {
        self.rows.iter().find(|r| r.workload == workload)
    }
}

fn point(
    label: String,
    spec_edit: impl Fn(&mut RduConfig),
    dc: &DecoderConfig,
    workloads: &[&'static dyn Workload],
) -> SweepPoint {
    let rows = workloads
        .iter()
        .map(|w| {
            let mut base = RduConfig::baseline();
            spec_edit(&mut base);
            let mut ext = w.extended_config();
            spec_edit(&mut ext);
            let g = w.build_graph(dc);
            let base_s = estimate(&g, &base).expect("mappable").total_seconds;
            let ext_s = estimate(&g, &ext).expect("mappable").total_seconds;
            WorkloadPoint { workload: w.name(), seconds: ext_s, gain: base_s / ext_s }
        })
        .collect();
    SweepPoint { label, rows }
}

/// Sweep the PCU count (chip scale) at fixed geometry. SRAM (PMU count) is
/// held at the Table I capacity so the sweep isolates *compute* scale —
/// shrinking SRAM too would conflate it with the sectioning threshold.
pub fn sweep_pcu_count(
    dc: &DecoderConfig,
    counts: &[usize],
    workloads: &[&'static dyn Workload],
) -> Vec<SweepPoint> {
    counts
        .iter()
        .map(|&n| point(format!("{n} PCUs"), |cfg| cfg.spec.n_pcu = n, dc, workloads))
        .collect()
}

/// Sweep off-chip bandwidth (memory technology).
pub fn sweep_bandwidth(
    dc: &DecoderConfig,
    techs: &[MemTech],
    workloads: &[&'static dyn Workload],
) -> Vec<SweepPoint> {
    techs
        .iter()
        .map(|&t| point(format!("{t}"), |cfg| cfg.spec.dram = t, dc, workloads))
        .collect()
}

/// Sweep pipeline depth (stages) at fixed lane width — moves the
/// serialized-execution penalty (1/stages) and the spatial factor
/// (levels/stages) in opposite directions.
pub fn sweep_stages(
    dc: &DecoderConfig,
    stages: &[usize],
    workloads: &[&'static dyn Workload],
) -> Vec<SweepPoint> {
    stages
        .iter()
        .map(|&s| {
            point(
                format!("{s} stages"),
                |cfg| {
                    cfg.spec.pcu = crate::arch::PcuGeometry::new(cfg.spec.pcu.lanes, s);
                },
                dc,
                workloads,
            )
        })
        .collect()
}

/// Fusion ablation at one design point: launch-granularity latency of the
/// fused vs kernel-by-kernel mapping on each workload's extended config, as
/// `(name, unfused/fused)` rows. The `sweep --fuse` CLI path prints this
/// next to each swept point.
pub fn fusion_gains(
    dc: &DecoderConfig,
    workloads: &[&'static dyn Workload],
) -> Vec<(&'static str, f64)> {
    use super::perf::{estimate_fused, estimate_unfused};
    workloads
        .iter()
        .map(|w| {
            let g = w.build_graph(dc);
            let cfg = w.extended_config();
            let gain = estimate_unfused(&g, &cfg).expect("mappable").total_seconds
                / estimate_fused(&g, &cfg).expect("mappable").total_seconds;
            (w.name(), gain)
        })
        .collect()
}

/// Render a sweep as a table: one latency and one gain column per workload.
/// Shared by the `sweep` CLI subcommand and the `ablations` bench.
pub fn sweep_table(title: &str, pts: &[SweepPoint]) -> Table {
    let mut header: Vec<String> = vec!["Point".to_string()];
    if let Some(first) = pts.first() {
        for r in &first.rows {
            header.push(r.workload.to_string());
            header.push(format!("{} gain", r.workload));
        }
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &header_refs);
    for p in pts {
        let mut cells = vec![p.label.clone()];
        for r in &p.rows {
            cells.push(fmt_time(r.seconds));
            cells.push(format!("{:.2}x", r.gain));
        }
        t.row(&cells);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{lookup, ssm_workloads};

    fn dc() -> DecoderConfig {
        DecoderConfig::paper(1 << 18)
    }

    fn pair() -> Vec<&'static dyn Workload> {
        vec![lookup("hyena").unwrap(), lookup("mamba").unwrap()]
    }

    #[test]
    fn more_pcus_never_slower() {
        let pts = sweep_pcu_count(&dc(), &[128, 256, 520], &ssm_workloads());
        for w in pts.windows(2) {
            for (a, b) in w[0].rows.iter().zip(&w[1].rows) {
                assert!(b.seconds <= a.seconds * 1.001, "{}: {a:?} -> {b:?}", a.workload);
            }
        }
    }

    #[test]
    fn more_bandwidth_never_slower() {
        let pts =
            sweep_bandwidth(&dc(), &[MemTech::Ddr5, MemTech::Hbm2e, MemTech::Hbm3e], &pair());
        for w in pts.windows(2) {
            let (a, b) = (w[0].row("hyena").unwrap(), w[1].row("hyena").unwrap());
            assert!(b.seconds <= a.seconds * 1.001, "{w:?}");
        }
    }

    #[test]
    fn deeper_pipelines_raise_extension_gain() {
        // The serialized penalty is 1/stages, so the FFT-mode gain grows
        // with pipeline depth — the paper's architectural argument in
        // ablation form.
        let pts = sweep_stages(&dc(), &[6, 12, 24], &pair());
        for w in pts.windows(2) {
            let (a, b) = (w[0].row("hyena").unwrap(), w[1].row("hyena").unwrap());
            let msg = format!("{} {} vs {} {}", w[0].label, a.gain, w[1].label, b.gain);
            assert!(b.gain >= a.gain * 0.999, "{msg}");
        }
    }

    #[test]
    fn gains_always_at_least_one() {
        for p in sweep_pcu_count(&dc(), &[64, 520], &ssm_workloads()) {
            for r in &p.rows {
                assert!(r.gain >= 1.0 - 1e-9, "{r:?}");
            }
        }
    }

    #[test]
    fn ssd_needs_no_extension() {
        // SSD's extended config *is* the baseline: the chunked matmuls run
        // systolic everywhere, so its sweep gain is identically 1.
        for p in sweep_pcu_count(&dc(), &[260, 520], &[lookup("ssd").unwrap()]) {
            let r = p.row("ssd").unwrap();
            assert!((r.gain - 1.0).abs() < 1e-12, "{r:?}");
            assert!(r.seconds.is_finite() && r.seconds > 0.0);
        }
    }

    #[test]
    fn fusion_gains_exceed_one_for_every_ssm() {
        for (name, gain) in fusion_gains(&DecoderConfig::paper(1 << 14), &ssm_workloads()) {
            assert!(gain > 1.0, "{name} fusion gain {gain}");
        }
    }

    #[test]
    fn sweep_table_renders_all_workloads() {
        let pts = sweep_pcu_count(&DecoderConfig::paper(1 << 14), &[520], &ssm_workloads());
        let s = sweep_table("t", &pts).render();
        for name in ["hyena", "mamba", "ssd", "s4"] {
            assert!(s.contains(name), "{s}");
        }
    }
}
