//! Design-space exploration: sweep chip parameters (PCU count, geometry,
//! memory bandwidth, Bailey tile size) and report how the paper's headline
//! results move — the ablation study DFModel (paper Fig. 4: "multi-level
//! optimization … design space optimization") was built for.

use super::perf::estimate;
use crate::arch::{MemTech, RduConfig};
use crate::fft::BaileyVariant;
use crate::workloads::{hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant};

/// One swept design point.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub label: String,
    /// Hyena (Vector-FFT) latency on the extended config.
    pub hyena_seconds: f64,
    /// Mamba (parallel-scan) latency on the extended config.
    pub mamba_seconds: f64,
    /// Speedup of the extended config over the baseline config at this
    /// design point (Hyena / Mamba).
    pub hyena_gain: f64,
    pub mamba_gain: f64,
}

fn point(label: String, spec_edit: impl Fn(&mut RduConfig), dc: &DecoderConfig) -> SweepPoint {
    let mut base = RduConfig::baseline();
    spec_edit(&mut base);
    let mut fftm = RduConfig::fft_mode();
    spec_edit(&mut fftm);
    let mut scanm = RduConfig::hs_scan_mode();
    spec_edit(&mut scanm);

    let hy = hyena_decoder(dc, BaileyVariant::Vector);
    let ma = mamba_decoder(dc, ScanVariant::Parallel);
    let hy_base = estimate(&hy, &base).expect("mappable").total_seconds;
    let hy_ext = estimate(&hy, &fftm).expect("mappable").total_seconds;
    let ma_base = estimate(&ma, &base).expect("mappable").total_seconds;
    let ma_ext = estimate(&ma, &scanm).expect("mappable").total_seconds;
    SweepPoint {
        label,
        hyena_seconds: hy_ext,
        mamba_seconds: ma_ext,
        hyena_gain: hy_base / hy_ext,
        mamba_gain: ma_base / ma_ext,
    }
}

/// Sweep the PCU count (chip scale) at fixed geometry. SRAM (PMU count) is
/// held at the Table I capacity so the sweep isolates *compute* scale —
/// shrinking SRAM too would conflate it with the sectioning threshold.
pub fn sweep_pcu_count(dc: &DecoderConfig, counts: &[usize]) -> Vec<SweepPoint> {
    counts
        .iter()
        .map(|&n| point(format!("{n} PCUs"), |cfg| cfg.spec.n_pcu = n, dc))
        .collect()
}

/// Sweep off-chip bandwidth (memory technology).
pub fn sweep_bandwidth(dc: &DecoderConfig, techs: &[MemTech]) -> Vec<SweepPoint> {
    techs
        .iter()
        .map(|&t| point(format!("{t}"), |cfg| cfg.spec.dram = t, dc))
        .collect()
}

/// Sweep pipeline depth (stages) at fixed lane width — moves the
/// serialized-execution penalty (1/stages) and the spatial factor
/// (levels/stages) in opposite directions.
pub fn sweep_stages(dc: &DecoderConfig, stages: &[usize]) -> Vec<SweepPoint> {
    stages
        .iter()
        .map(|&s| {
            point(format!("{} stages", s), |cfg| {
                cfg.spec.pcu = crate::arch::PcuGeometry::new(cfg.spec.pcu.lanes, s);
            }, dc)
        })
        .collect()
}

/// Fusion ablation at one design point: launch-granularity latency of the
/// fused vs kernel-by-kernel mapping on the extended configs, as
/// `(hyena_gain, mamba_gain)` where gain = unfused / fused. The `sweep
/// --fuse` CLI path prints this next to each swept point.
pub fn fusion_gain_at(dc: &DecoderConfig) -> (f64, f64) {
    use super::perf::{estimate_fused, estimate_unfused};
    let hy = hyena_decoder(dc, BaileyVariant::Vector);
    let ma = mamba_decoder(dc, ScanVariant::Parallel);
    let fftm = RduConfig::fft_mode();
    let scanm = RduConfig::hs_scan_mode();
    let hy_gain = estimate_unfused(&hy, &fftm).expect("mappable").total_seconds
        / estimate_fused(&hy, &fftm).expect("mappable").total_seconds;
    let ma_gain = estimate_unfused(&ma, &scanm).expect("mappable").total_seconds
        / estimate_fused(&ma, &scanm).expect("mappable").total_seconds;
    (hy_gain, ma_gain)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dc() -> DecoderConfig {
        DecoderConfig::paper(1 << 18)
    }

    #[test]
    fn more_pcus_never_slower() {
        let pts = sweep_pcu_count(&dc(), &[128, 256, 520]);
        for w in pts.windows(2) {
            assert!(w[1].hyena_seconds <= w[0].hyena_seconds * 1.001, "{w:?}");
            assert!(w[1].mamba_seconds <= w[0].mamba_seconds * 1.001, "{w:?}");
        }
    }

    #[test]
    fn more_bandwidth_never_slower() {
        let pts = sweep_bandwidth(&dc(), &[MemTech::Ddr5, MemTech::Hbm2e, MemTech::Hbm3e]);
        for w in pts.windows(2) {
            assert!(w[1].hyena_seconds <= w[0].hyena_seconds * 1.001, "{w:?}");
        }
    }

    #[test]
    fn deeper_pipelines_raise_extension_gain() {
        // The serialized penalty is 1/stages, so the FFT-mode gain grows
        // with pipeline depth — the paper's architectural argument in
        // ablation form.
        let pts = sweep_stages(&dc(), &[6, 12, 24]);
        for w in pts.windows(2) {
            assert!(
                w[1].hyena_gain >= w[0].hyena_gain * 0.999,
                "{} {} vs {} {}",
                w[0].label,
                w[0].hyena_gain,
                w[1].label,
                w[1].hyena_gain
            );
        }
    }

    #[test]
    fn gains_always_at_least_one() {
        for p in sweep_pcu_count(&dc(), &[64, 520]) {
            assert!(p.hyena_gain >= 1.0 && p.mamba_gain >= 1.0, "{p:?}");
        }
    }

    #[test]
    fn fusion_gains_exceed_one() {
        let (hy, ma) = fusion_gain_at(&DecoderConfig::paper(1 << 14));
        assert!(hy > 1.0, "hyena fusion gain {hy}");
        assert!(ma > 1.0, "mamba fusion gain {ma}");
    }
}
