//! Reproduction of DFModel [20] — the dataflow performance-modeling
//! framework every figure in the paper is produced with (paper §II-C,
//! Fig. 4): *"DFModel takes a workload and a system configuration as
//! inputs, performs a multi-level optimization process to identify the
//! optimal dataflow mapping, and estimates the corresponding performance."*
//!
//! * [`throughput`] — per-kernel effective rates on an RDU configuration,
//!   grounded in the cycle-level PCU measurements of
//!   [`crate::pcusim::utilization`].
//! * [`mapping`] — the mapping optimizer: balanced PCU/PMU allocation and
//!   SRAM-capacity sectioning.
//! * [`fusion`] — the fusion pass: clusters producer→consumer stream chains
//!   (FFT→eltwise→iFFT, scan→gate→proj) into single spatially-mapped
//!   sections whose intermediates stay in PCU/PMU SRAM.
//! * [`perf`] — the latency estimator: per-section pipeline bottleneck,
//!   overlapped DRAM streaming, per-kernel and per-op-class breakdowns;
//!   [`estimate_fused`]/[`estimate_unfused`] price fusion-plan launches
//!   (fabric reconfigurations + DRAM-staged cut tensors) so the fusion win
//!   is a modeled, testable number.
//! * [`decode`] — the decode-step cost hook: O(1)-per-token cycle/latency
//!   model that drives the [`crate::session`] continuous-batching
//!   scheduler in simulation, without a PJRT backend; `decode_step_sharded`
//!   adds the per-layer all-reduce of a chips-partitioned step.
//!
//! Every entry point consumes workloads through the
//! [`mod@crate::workloads::registry`]: `sweep`'s design tables, the fusion
//! gains and the decode hook all take (or resolve) a
//! [`crate::workloads::Workload`] trait object, so a newly registered SSM
//! variant is swept, fused and priced with no changes in this module.
//!
//! The GPU and VGA comparison backends live in [`crate::gpu`] and
//! [`crate::vga`]; they consume the same [`crate::graph::Graph`] workloads.
//! Multi-chip deployments are priced by [`crate::shard::estimate`], which
//! composes [`estimate`] at `L / chips` with the
//! [`crate::arch::InterchipLink`] communication term.

pub mod decode;
pub mod fusion;
pub mod mapping;
pub mod perf;
pub mod sweep;
pub mod throughput;

pub use decode::{
    decode_step, decode_step_sharded, decode_step_unfused, decode_step_workload, DecodeCost,
    ShardedDecodeCost, DECODE_KERNELS_PER_LAYER, DECODE_UTIL,
};
pub use fusion::{fuse_graph, FusionPlan};
pub use mapping::{map_graph, map_graph_plan, Allocation, MapFailure, Mapping, Section};
pub use perf::{
    estimate, estimate_fused, estimate_plan, estimate_unfused, Attribution, Estimate,
    KernelEstimate,
};
pub use sweep::{
    fusion_gains, sweep_bandwidth, sweep_pcu_count, sweep_stages, sweep_table, SweepPoint,
    WorkloadPoint,
};
pub use throughput::{kernel_rate, pcu_seconds, reconfig_seconds, Rate, RECONFIG_CYCLES};
