//! DFModel performance estimator: dataflow-execution latency of a workload
//! graph on an RDU configuration (paper Fig. 4: workload + system config →
//! optimal mapping → performance).
//!
//! Under dataflow execution (Fig. 1B) every kernel of a section runs
//! concurrently as a stage of an on-chip pipeline, so a section's
//! steady-state latency is its *bottleneck* kernel time, and DRAM traffic is
//! only the graph's external inputs/outputs (+ weights, loaded once) —
//! intermediates never leave the chip. Compute and memory streams overlap;
//! the section takes `max(compute, memory)`.

use super::fusion::{fuse_graph, FusionPlan};
use super::mapping::{map_graph, map_graph_plan, MapFailure, Mapping};
use super::throughput::reconfig_seconds;
use crate::arch::RduConfig;
use crate::graph::{Graph, OpClass};
use std::collections::BTreeMap;

/// Per-kernel line item of an estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelEstimate {
    pub name: String,
    pub op: OpClass,
    pub flops: f64,
    pub pcus: usize,
    /// Kernel time under its allocation (pipeline stage interval).
    pub seconds: f64,
}

/// Performance estimate for one graph on one RDU configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Estimate {
    pub graph_name: String,
    pub cfg_name: String,
    /// End-to-end latency: Σ over sections of max(compute, memory).
    pub total_seconds: f64,
    /// Compute component (Σ section pipeline intervals).
    pub compute_seconds: f64,
    /// Memory component (graph I/O + weights at DRAM bandwidth).
    pub memory_seconds: f64,
    /// Fabric-reconfiguration share of `compute_seconds` (launch-granularity
    /// estimates only; 0 for the idealized whole-graph dataflow bound).
    pub reconfig_seconds: f64,
    pub sections: usize,
    pub kernels: Vec<KernelEstimate>,
}

/// Where an estimate's modeled time goes — the cycle-attribution view the
/// paper's Fig. 7/11 speedup claims rest on. Components are overlapping
/// demand streams (dataflow execution takes their max, not their sum), so
/// shares are reported against total demand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Attribution {
    /// Pipeline compute, excluding reconfiguration.
    pub compute_seconds: f64,
    /// Fabric reconfiguration between launches.
    pub reconfig_seconds: f64,
    /// DRAM round-trips: external I/O, weights, staged intermediates.
    pub dram_seconds: f64,
    /// Inter-chip exchange (0 for single-chip estimates; filled by
    /// [`crate::shard::ShardedEstimate::attribution`]).
    pub interchip_seconds: f64,
}

impl Attribution {
    /// Total demand across all four streams.
    pub fn demand_seconds(&self) -> f64 {
        self.compute_seconds + self.reconfig_seconds + self.dram_seconds + self.interchip_seconds
    }

    /// One-line `compute/reconfig/dram/interchip` percentage breakdown.
    pub fn summary(&self) -> String {
        let d = self.demand_seconds();
        if d <= 0.0 {
            return "no demand".to_string();
        }
        format!(
            "compute {:.1}% + reconfig {:.1}% + dram {:.1}% + interchip {:.1}% of {} demand",
            100.0 * self.compute_seconds / d,
            100.0 * self.reconfig_seconds / d,
            100.0 * self.dram_seconds / d,
            100.0 * self.interchip_seconds / d,
            crate::util::fmt_time(d),
        )
    }
}

impl Estimate {
    /// Name of the slowest kernel (the pipeline bottleneck).
    pub fn bottleneck(&self) -> &str {
        self.kernels
            .iter()
            .max_by(|a, b| a.seconds.partial_cmp(&b.seconds).unwrap())
            .map(|k| k.name.as_str())
            .unwrap_or("-")
    }

    /// Attribute the total latency to op classes proportionally to kernel
    /// demand — the Fig. 7/11 "latency breakdown" view.
    pub fn breakdown_by_op(&self) -> BTreeMap<&'static str, f64> {
        let total_demand: f64 = self.kernels.iter().map(|k| k.seconds * k.pcus as f64).sum();
        let mut m = BTreeMap::new();
        if total_demand <= 0.0 {
            return m;
        }
        for k in &self.kernels {
            *m.entry(k.op.label()).or_insert(0.0) +=
                self.total_seconds * (k.seconds * k.pcus as f64) / total_demand;
        }
        m
    }

    /// Cycle attribution of this estimate: compute vs reconfiguration vs
    /// DRAM round-trips (interchip stays 0 here — the sharded estimates
    /// fill it in).
    pub fn attribution(&self) -> Attribution {
        Attribution {
            compute_seconds: (self.compute_seconds - self.reconfig_seconds).max(0.0),
            reconfig_seconds: self.reconfig_seconds,
            dram_seconds: self.memory_seconds,
            interchip_seconds: 0.0,
        }
    }

    /// Latency attributed to a kernel-name predicate (e.g. the FFT share).
    pub fn share_where(&self, pred: impl Fn(&KernelEstimate) -> bool) -> f64 {
        let total_demand: f64 = self.kernels.iter().map(|k| k.seconds * k.pcus as f64).sum();
        if total_demand <= 0.0 {
            return 0.0;
        }
        let sel: f64 = self
            .kernels
            .iter()
            .filter(|k| pred(k))
            .map(|k| k.seconds * k.pcus as f64)
            .sum();
        self.total_seconds * sel / total_demand
    }
}

/// Estimate dataflow-execution latency of `g` on `cfg`.
///
/// The mapper allocates PCUs/PMUs across the graph's kernels (sectioning
/// when resident state exceeds SRAM), then the estimate is the pipelined
/// `max(compute, memory)` per section:
///
/// ```
/// use ssm_rdu::arch::RduConfig;
/// use ssm_rdu::dfmodel::estimate;
/// use ssm_rdu::fft::BaileyVariant;
/// use ssm_rdu::workloads::{hyena_decoder, DecoderConfig};
///
/// let g = hyena_decoder(&DecoderConfig::paper(1 << 16), BaileyVariant::Vector);
/// let baseline = estimate(&g, &RduConfig::baseline()).unwrap();
/// let extended = estimate(&g, &RduConfig::fft_mode()).unwrap();
/// // The FFT-mode interconnect extension makes the same workload faster.
/// assert!(extended.total_seconds < baseline.total_seconds);
/// assert!(baseline.bottleneck().contains("fft"));
/// ```
pub fn estimate(g: &Graph, cfg: &RduConfig) -> Result<Estimate, MapFailure> {
    let _t = crate::telemetry::span("dfmodel", "dfmodel.estimate");
    estimates_counter().fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mapping = map_graph(g, cfg)?;
    Ok(estimate_with_mapping(g, cfg, &mapping))
}

/// The `dfmodel.estimates` counter, resolved once.
fn estimates_counter() -> &'static std::sync::atomic::AtomicU64 {
    static CELL: std::sync::OnceLock<&'static std::sync::atomic::AtomicU64> =
        std::sync::OnceLock::new();
    CELL.get_or_init(|| crate::telemetry::counter("dfmodel.estimates"))
}

/// Estimate with a precomputed mapping (lets callers inspect the mapping).
pub fn estimate_with_mapping(g: &Graph, cfg: &RduConfig, mapping: &Mapping) -> Estimate {
    let bw = cfg.spec.dram_bandwidth();

    // Memory: external inputs + outputs + weights, streamed once, plus
    // section-boundary tensors staged through DRAM when sectioned.
    let boundary_bytes = if mapping.sections.len() > 1 {
        // Approximate: each extra section boundary re-stages one activation
        // tensor of the largest intermediate size.
        (mapping.sections.len() - 1) as f64 * g.max_intermediate_bytes() * 2.0
    } else {
        0.0
    };
    let io_bytes = g.external_input_bytes() + g.external_output_bytes() + g.total_weight_bytes()
        + boundary_bytes;
    let memory_seconds = io_bytes / bw;

    let compute_seconds = mapping.compute_seconds();
    // Compute and DRAM streams overlap under dataflow execution.
    let total_seconds = compute_seconds.max(memory_seconds);

    let mut kernels = Vec::with_capacity(g.kernels.len());
    for s in &mapping.sections {
        for a in &s.allocs {
            let k = &g.kernels[a.kernel];
            kernels.push(KernelEstimate {
                name: k.name.clone(),
                op: k.op,
                flops: k.flops,
                pcus: a.pcus,
                seconds: a.time,
            });
        }
    }

    Estimate {
        graph_name: g.name.clone(),
        cfg_name: cfg.name(),
        total_seconds,
        compute_seconds,
        memory_seconds,
        reconfig_seconds: 0.0,
        sections: mapping.sections.len(),
        kernels,
    }
}

/// Launch-granularity estimate of a fusion plan: each cluster is one
/// spatial-program launch (one fabric reconfiguration + a pipelined section
/// whose steady-state interval is its bottleneck stage), and every
/// intermediate tensor that crosses a cluster boundary is staged through
/// DRAM (written by the producer's section, re-read by the consumer's).
///
/// This sits between the two classical models: with the
/// [`FusionPlan::unfused`] plan it prices kernel-by-kernel execution
/// (paper Fig. 1C — every intermediate round-trips DRAM, one launch per
/// kernel), and as clusters grow it approaches the idealized whole-graph
/// dataflow bound of [`estimate`] (Fig. 1B) plus one reconfiguration.
pub fn estimate_plan(
    g: &Graph,
    cfg: &RduConfig,
    plan: &FusionPlan,
) -> Result<Estimate, MapFailure> {
    let _t = crate::telemetry::span("dfmodel", "dfmodel.estimate_plan");
    estimates_counter().fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mapping = map_graph_plan(g, cfg, &plan.clusters)?;
    let bw = cfg.spec.dram_bandwidth();

    // Memory: external I/O + weights, plus a DRAM write + read for every
    // intermediate tensor the plan does not keep on-chip.
    let staged = plan.staged_intermediate_bytes(g);
    let io_bytes = g.external_input_bytes()
        + g.external_output_bytes()
        + g.total_weight_bytes()
        + 2.0 * staged;
    let memory_seconds = io_bytes / bw;

    // Compute: the sections run back-to-back, each paying one fabric
    // reconfiguration plus its pipeline interval.
    let reconfig = plan.launches() as f64 * reconfig_seconds(cfg);
    let compute_seconds = mapping.compute_seconds() + reconfig;
    let total_seconds = compute_seconds.max(memory_seconds);

    let mut kernels = Vec::with_capacity(g.kernels.len());
    for s in &mapping.sections {
        for a in &s.allocs {
            let k = &g.kernels[a.kernel];
            kernels.push(KernelEstimate {
                name: k.name.clone(),
                op: k.op,
                flops: k.flops,
                pcus: a.pcus,
                seconds: a.time,
            });
        }
    }

    Ok(Estimate {
        graph_name: g.name.clone(),
        cfg_name: cfg.name(),
        total_seconds,
        compute_seconds,
        memory_seconds,
        reconfig_seconds: reconfig,
        sections: mapping.sections.len(),
        kernels,
    })
}

/// Launch-granularity estimate under the fusion pass: stream chains fused
/// into single sections (intermediates SRAM-resident), cut tensors staged.
pub fn estimate_fused(g: &Graph, cfg: &RduConfig) -> Result<Estimate, MapFailure> {
    estimate_plan(g, cfg, &fuse_graph(g, cfg))
}

/// Launch-granularity estimate of kernel-by-kernel execution: one launch
/// per kernel, every intermediate through DRAM — the unfused baseline the
/// fusion speedup is measured against.
pub fn estimate_unfused(g: &Graph, cfg: &RduConfig) -> Result<Estimate, MapFailure> {
    estimate_plan(g, cfg, &FusionPlan::unfused(g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::BaileyVariant;
    use crate::workloads::{
        attention_decoder, hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant,
    };

    fn paper_1m() -> DecoderConfig {
        DecoderConfig::paper(1 << 20)
    }

    #[test]
    fn attention_slowest_of_all_designs() {
        // Fig. 7 / Fig. 11 Design 1: attention has the highest latency.
        let cfg = paper_1m();
        let base = RduConfig::baseline();
        let at = estimate(&attention_decoder(&cfg), &base).unwrap().total_seconds;
        let hy = estimate(&hyena_decoder(&cfg, BaileyVariant::Vector), &base).unwrap().total_seconds;
        let ma = estimate(&mamba_decoder(&cfg, ScanVariant::Parallel), &base).unwrap().total_seconds;
        assert!(at > hy && at > ma, "at={at} hy={hy} ma={ma}");
    }

    #[test]
    fn fig7_design_ordering() {
        // Fig. 7: attention > VecFFT/baseline > GEMM-FFT/baseline >
        // VecFFT/FFT-mode.
        let cfg = paper_1m();
        let base = RduConfig::baseline();
        let fftm = RduConfig::fft_mode();
        let d1 = estimate(&attention_decoder(&cfg), &base).unwrap().total_seconds;
        let d2 = estimate(&hyena_decoder(&cfg, BaileyVariant::Vector), &base).unwrap().total_seconds;
        let d3 = estimate(&hyena_decoder(&cfg, BaileyVariant::Gemm), &base).unwrap().total_seconds;
        let d4 = estimate(&hyena_decoder(&cfg, BaileyVariant::Vector), &fftm).unwrap().total_seconds;
        assert!(d1 > d2 && d2 > d3 && d3 > d4, "d1={d1} d2={d2} d3={d3} d4={d4}");
        // Paper headline factors (shape check, generous bands):
        let s21 = d1 / d2; // paper 217.74×
        let s32 = d2 / d3; // paper 2.61×
        let s43 = d3 / d4; // paper 1.95×
        assert!(s21 > 50.0, "s21={s21}");
        assert!(s32 > 1.2 && s32 < 6.0, "s32={s32}");
        assert!(s43 > 1.2 && s43 < 6.0, "s43={s43}");
    }

    #[test]
    fn fig11_design_ordering() {
        // Fig. 11: attention > C-scan > parallel/baseline > parallel/scan-mode.
        let cfg = paper_1m();
        let base = RduConfig::baseline();
        let d1 = estimate(&attention_decoder(&cfg), &base).unwrap().total_seconds;
        let d2 = estimate(&mamba_decoder(&cfg, ScanVariant::CScan), &base).unwrap().total_seconds;
        let d3 = estimate(&mamba_decoder(&cfg, ScanVariant::Parallel), &base).unwrap().total_seconds;
        let d4 = estimate(&mamba_decoder(&cfg, ScanVariant::Parallel), &RduConfig::hs_scan_mode())
            .unwrap()
            .total_seconds;
        let d5 = estimate(&mamba_decoder(&cfg, ScanVariant::Parallel), &RduConfig::b_scan_mode())
            .unwrap()
            .total_seconds;
        assert!(d1 > d2 && d2 > d3 && d3 > d4, "d1={d1} d2={d2} d3={d3} d4={d4}");
        // Paper: HS-mode and B-mode identical.
        assert!((d4 - d5).abs() / d4 < 0.01, "d4={d4} d5={d5}");
        // Paper headline factors (shape):
        assert!(d1 / d2 > 2.0, "d1/d2={}", d1 / d2); // paper 7.34×
        assert!(d2 / d3 > 100.0, "d2/d3={}", d2 / d3); // paper 562.98×
        let s = d3 / d4; // paper 1.75×
        assert!(s > 1.05 && s < 3.0, "d3/d4={s}");
    }

    #[test]
    fn speedups_stable_across_sweep() {
        // Paper: "achieves a 1.95× speedup … across different sequence
        // lengths" — the design-vs-design ratios are ~constant over L.
        let base = RduConfig::baseline();
        let fftm = RduConfig::fft_mode();
        let mut ratios = Vec::new();
        for dc in DecoderConfig::paper_sweep() {
            let d3 = estimate(&hyena_decoder(&dc, BaileyVariant::Gemm), &base).unwrap().total_seconds;
            let d4 = estimate(&hyena_decoder(&dc, BaileyVariant::Vector), &fftm).unwrap().total_seconds;
            ratios.push(d3 / d4);
        }
        let spread = ratios.iter().cloned().fold(0.0f64, f64::max)
            / ratios.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 1.1, "ratios={ratios:?}");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let cfg = paper_1m();
        let e = estimate(&hyena_decoder(&cfg, BaileyVariant::Vector), &RduConfig::fft_mode()).unwrap();
        let sum: f64 = e.breakdown_by_op().values().sum();
        assert!((sum - e.total_seconds).abs() / e.total_seconds < 1e-9);
    }

    #[test]
    fn memory_model_nonzero_and_overlapped() {
        let cfg = paper_1m();
        let e = estimate(&hyena_decoder(&cfg, BaileyVariant::Vector), &RduConfig::fft_mode()).unwrap();
        assert!(e.memory_seconds > 0.0);
        assert!(e.total_seconds >= e.memory_seconds);
        assert!(e.total_seconds >= e.compute_seconds * 0.999);
    }

    #[test]
    fn bottleneck_is_fft_on_baseline_hyena() {
        let cfg = paper_1m();
        let e = estimate(&hyena_decoder(&cfg, BaileyVariant::Vector), &RduConfig::baseline()).unwrap();
        assert!(e.bottleneck().contains("fft"), "bottleneck={}", e.bottleneck());
    }

    #[test]
    fn fused_strictly_beats_unfused_across_lengths() {
        // The ISSUE-3 acceptance shape: fusion must be a strict win for both
        // SSM decoders at L = 4K, and keep winning as L grows.
        for l in [1 << 12, 1 << 16, 1 << 20] {
            let dc = DecoderConfig::paper(l);
            let hy = hyena_decoder(&dc, BaileyVariant::Vector);
            let ma = mamba_decoder(&dc, ScanVariant::Parallel);
            for (g, cfg) in [(&hy, RduConfig::fft_mode()), (&ma, RduConfig::hs_scan_mode())] {
                let f = estimate_fused(g, &cfg).unwrap();
                let u = estimate_unfused(g, &cfg).unwrap();
                assert!(
                    f.total_seconds < u.total_seconds,
                    "L={l} {}: fused {} !< unfused {}",
                    g.name,
                    f.total_seconds,
                    u.total_seconds
                );
                assert!(f.sections < u.sections, "fusion must reduce launches");
                assert!(f.memory_seconds <= u.memory_seconds);
            }
        }
    }

    #[test]
    fn fused_approaches_idealized_dataflow_bound() {
        // The idealized estimate (whole graph as one resident pipeline,
        // intermediates free) lower-bounds the launch-granularity model up
        // to reconfiguration; fused must land between it and unfused.
        let dc = DecoderConfig::paper(1 << 16);
        let g = mamba_decoder(&dc, ScanVariant::Parallel);
        let cfg = RduConfig::hs_scan_mode();
        let ideal = estimate(&g, &cfg).unwrap().total_seconds;
        let fused = estimate_fused(&g, &cfg).unwrap().total_seconds;
        let unfused = estimate_unfused(&g, &cfg).unwrap().total_seconds;
        assert!(ideal <= fused * 1.0000001, "ideal {ideal} > fused {fused}");
        assert!(fused < unfused);
    }

    #[test]
    fn unfused_charges_every_intermediate_to_dram() {
        let dc = DecoderConfig::paper(1 << 14);
        let g = hyena_decoder(&dc, BaileyVariant::Vector);
        let cfg = RduConfig::fft_mode();
        let u = estimate_unfused(&g, &cfg).unwrap();
        let expect = (g.external_input_bytes()
            + g.external_output_bytes()
            + g.total_weight_bytes()
            + 2.0 * g.intermediate_bytes())
            / cfg.spec.dram_bandwidth();
        assert!((u.memory_seconds - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn attribution_partitions_compute_and_charges_reconfig_only_on_launches() {
        let dc = DecoderConfig::paper(1 << 14);
        let g = hyena_decoder(&dc, BaileyVariant::Vector);
        let cfg = RduConfig::fft_mode();

        // Idealized estimate: no launches, so no reconfiguration share.
        let ideal = estimate(&g, &cfg).unwrap();
        let a = ideal.attribution();
        assert_eq!(a.reconfig_seconds, 0.0);
        assert_eq!(a.interchip_seconds, 0.0);
        assert!((a.compute_seconds - ideal.compute_seconds).abs() < 1e-15);
        assert!((a.dram_seconds - ideal.memory_seconds).abs() < 1e-15);

        // Launch-granularity estimate: reconfiguration is a strict, separable
        // component of the compute stream.
        let unfused = estimate_unfused(&g, &cfg).unwrap();
        let u = unfused.attribution();
        assert!(u.reconfig_seconds > 0.0);
        assert!(
            (u.compute_seconds + u.reconfig_seconds - unfused.compute_seconds).abs()
                / unfused.compute_seconds
                < 1e-12
        );
        // Fusing reduces launches, so it must shrink the reconfig share.
        let fused = estimate_fused(&g, &cfg).unwrap().attribution();
        assert!(fused.reconfig_seconds < u.reconfig_seconds);
        let line = u.summary();
        assert!(line.contains("reconfig") && line.contains('%'), "{line}");
    }

    #[test]
    fn estimate_plan_breakdown_still_covers_all_kernels() {
        let dc = DecoderConfig::paper(1 << 14);
        let g = mamba_decoder(&dc, ScanVariant::CScan);
        let cfg = RduConfig::b_scan_mode();
        let f = estimate_fused(&g, &cfg).unwrap();
        assert_eq!(f.kernels.len(), g.kernels.len());
        let sum: f64 = f.breakdown_by_op().values().sum();
        assert!((sum - f.total_seconds).abs() / f.total_seconds < 1e-9);
    }
}
