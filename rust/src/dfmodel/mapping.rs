//! Dataflow mapping optimizer: allocate PCUs/PMUs to every kernel of a
//! workload graph so the on-chip pipeline is balanced (paper §III-B: "it is
//! essential to optimally allocate resources to each kernel within the
//! graph. This ensures a balanced on-chip pipeline, thereby achieving
//! maximum overall throughput. DFModel addresses this challenge…").
//!
//! When a graph's resident state exceeds on-chip SRAM the mapper *sections*
//! it: contiguous topological chunks execute one after another with the
//! section-boundary tensors staged through DRAM — DFModel's multi-level
//! optimization's outer loop.

use super::throughput::{is_serial, pcu_seconds};
use crate::arch::RduConfig;
use crate::graph::{Graph, KernelId};

/// Resource assignment for one kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub kernel: KernelId,
    /// PCUs granted (≥ 1; serial kernels always get exactly 1).
    pub pcus: usize,
    /// PMUs granted (≥ 1).
    pub pmus: usize,
    /// Demand: seconds on a single PCU.
    pub pcu_seconds: f64,
    /// Achieved kernel time under this allocation.
    pub time: f64,
}

/// A contiguous chunk of the graph resident on-chip at once.
#[derive(Debug, Clone, PartialEq)]
pub struct Section {
    pub kernels: Vec<KernelId>,
    pub allocs: Vec<Allocation>,
    /// Bytes of weights + corner-turn buffers resident in PMUs.
    pub resident_bytes: f64,
    /// Steady-state pipeline interval: max kernel time in the section.
    pub pipeline_seconds: f64,
}

/// A complete mapping of a graph onto an RDU configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Mapping {
    pub sections: Vec<Section>,
    pub cfg_name: String,
}

/// Why a graph cannot be mapped.
#[derive(Debug, Clone, PartialEq)]
pub enum MapFailure {
    /// A single kernel's resident state exceeds total SRAM.
    KernelTooLarge { kernel: KernelId, name: String, bytes: f64, sram: f64 },
    /// Empty graph.
    EmptyGraph,
}

impl std::fmt::Display for MapFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapFailure::KernelTooLarge { kernel, name, bytes, sram } => {
                write!(
                    f,
                    "kernel `{name}` (id {kernel}) needs {bytes:.3e} B resident > \
                     {sram:.3e} B chip SRAM"
                )
            }
            MapFailure::EmptyGraph => write!(f, "empty graph"),
        }
    }
}

/// Bytes a kernel must keep resident in PMUs: its parameters plus — for the
/// FFT classes — its largest input tensor (Bailey's 4-step needs the
/// reshape/corner-turn buffered on-chip, §III-A). Streaming kernels only
/// need double-buffered tiles, charged as one PMU's worth.
pub fn resident_bytes(g: &Graph, id: KernelId, cfg: &RduConfig) -> f64 {
    use crate::graph::OpClass;
    let k = &g.kernels[id];
    let tile = cfg.spec.pmu_bytes as f64; // one PMU of stream buffering
    let corner_turn = match k.op {
        OpClass::VectorFft | OpClass::GemmFft => g
            .edges
            .iter()
            .filter(|e| e.dst == Some(id))
            .map(|e| e.bytes)
            .fold(0.0, f64::max),
        _ => 0.0,
    };
    k.weight_bytes + corner_turn + tile
}

/// Largest-remainder proportional allocation of `total` units by `weights`,
/// every entry ≥ 1. `fixed` entries are pinned to exactly 1 unit.
fn proportional(total: usize, weights: &[f64], fixed: &[bool]) -> Vec<usize> {
    let n = weights.len();
    assert!(total >= n, "need at least one unit per kernel: {total} < {n}");
    let mut alloc = vec![1usize; n];
    let mut spare = total - n;
    let free_weight: f64 = weights
        .iter()
        .zip(fixed)
        .filter(|(_, &f)| !f)
        .map(|(w, _)| *w)
        .sum();
    if free_weight <= 0.0 || spare == 0 {
        return alloc;
    }
    // Integer floor share + largest remainder.
    let mut rema: Vec<(usize, f64)> = Vec::new();
    let spare0 = spare;
    for i in 0..n {
        if fixed[i] {
            continue;
        }
        let share = weights[i] / free_weight * spare0 as f64;
        let fl = share.floor() as usize;
        let fl = fl.min(spare);
        alloc[i] += fl;
        spare -= fl;
        rema.push((i, share - share.floor()));
    }
    rema.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (i, _) in rema {
        if spare == 0 {
            break;
        }
        alloc[i] += 1;
        spare -= 1;
    }
    // Any remainder (all fixed): give to the heaviest free kernel, or drop.
    if spare > 0 {
        if let Some(i) = (0..n).filter(|&i| !fixed[i]).max_by(|&a, &b| {
            weights[a].partial_cmp(&weights[b]).unwrap()
        }) {
            alloc[i] += spare;
        }
    }
    alloc
}

/// SRAM-capacity check shared by the sectioning passes: error out — naming
/// the offending kernel — when a single kernel cannot fit on the chip.
fn check_kernel_fits(g: &Graph, id: KernelId, cfg: &RduConfig) -> Result<f64, MapFailure> {
    let sram = cfg.spec.sram_bytes() as f64;
    let rb = resident_bytes(g, id, cfg);
    if rb > sram {
        return Err(MapFailure::KernelTooLarge {
            kernel: id,
            name: g.kernels[id].name.clone(),
            bytes: rb,
            sram,
        });
    }
    Ok(rb)
}

/// Map `g` onto `cfg`, sectioning if the resident state exceeds SRAM.
pub fn map_graph(g: &Graph, cfg: &RduConfig) -> Result<Mapping, MapFailure> {
    if g.kernels.is_empty() {
        return Err(MapFailure::EmptyGraph);
    }
    let sram = cfg.spec.sram_bytes() as f64;
    let order = g.topo_order();

    // Pass 1: greedy sectioning along topological order.
    let mut sections_ids: Vec<Vec<KernelId>> = Vec::new();
    let mut cur: Vec<KernelId> = Vec::new();
    let mut cur_bytes = 0.0;
    for &id in &order {
        let rb = check_kernel_fits(g, id, cfg)?;
        let too_full = cur_bytes + rb > sram || cur.len() + 1 > cfg.spec.n_pcu;
        if too_full && !cur.is_empty() {
            sections_ids.push(std::mem::take(&mut cur));
            cur_bytes = 0.0;
        }
        cur.push(id);
        cur_bytes += rb;
    }
    if !cur.is_empty() {
        sections_ids.push(cur);
    }

    Ok(allocate(g, cfg, sections_ids))
}

/// Map `g` onto `cfg` with the section partition chosen by a fusion plan:
/// every cluster becomes one section that is configured onto the fabric as
/// a single spatial program. Unlike [`map_graph`]'s greedy packing, the
/// partition is caller-defined — [`super::fusion::fuse_graph`] guarantees
/// each cluster respects the SRAM and PCU-count capacity; this function
/// re-checks the per-kernel bound so pathological graphs still fail with a
/// named kernel instead of a nonsensical mapping.
pub fn map_graph_plan(
    g: &Graph,
    cfg: &RduConfig,
    clusters: &[Vec<KernelId>],
) -> Result<Mapping, MapFailure> {
    if g.kernels.is_empty() || clusters.iter().all(|c| c.is_empty()) {
        return Err(MapFailure::EmptyGraph);
    }
    for &id in clusters.iter().flatten() {
        check_kernel_fits(g, id, cfg)?;
    }
    let sections: Vec<Vec<KernelId>> =
        clusters.iter().filter(|c| !c.is_empty()).cloned().collect();
    Ok(allocate(g, cfg, sections))
}

/// Pass 2: balanced PCU/PMU allocation per section — each section gets the
/// whole chip while it is configured.
fn allocate(g: &Graph, cfg: &RduConfig, sections_ids: Vec<Vec<KernelId>>) -> Mapping {
    let mut sections = Vec::with_capacity(sections_ids.len());
    for ids in sections_ids {
        let demands: Vec<f64> = ids.iter().map(|&i| pcu_seconds(&g.kernels[i], cfg)).collect();
        let fixed: Vec<bool> = ids.iter().map(|&i| is_serial(&g.kernels[i])).collect();
        let pcu_alloc = proportional(cfg.spec.n_pcu, &demands, &fixed);
        let res: Vec<f64> = ids.iter().map(|&i| resident_bytes(g, i, cfg)).collect();
        let pmu_alloc = proportional(cfg.spec.n_pmu, &res, &vec![false; ids.len()]);

        let mut allocs = Vec::with_capacity(ids.len());
        let mut pipeline = 0.0f64;
        let mut resident = 0.0;
        for (j, &id) in ids.iter().enumerate() {
            let time = if fixed[j] { demands[j] } else { demands[j] / pcu_alloc[j] as f64 };
            pipeline = pipeline.max(time);
            resident += res[j];
            allocs.push(Allocation {
                kernel: id,
                pcus: pcu_alloc[j],
                pmus: pmu_alloc[j],
                pcu_seconds: demands[j],
                time,
            });
        }
        sections.push(Section {
            kernels: ids,
            allocs,
            resident_bytes: resident,
            pipeline_seconds: pipeline,
        });
    }

    Mapping { sections, cfg_name: cfg.name() }
}

impl Mapping {
    /// Total PCUs allocated in the busiest section (≤ chip PCUs invariant).
    pub fn max_pcus_used(&self) -> usize {
        self.sections
            .iter()
            .map(|s| s.allocs.iter().map(|a| a.pcus).sum::<usize>())
            .max()
            .unwrap_or(0)
    }

    /// Sum of the per-section pipeline intervals (the compute component of
    /// the total latency).
    pub fn compute_seconds(&self) -> f64 {
        self.sections.iter().map(|s| s.pipeline_seconds).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::BaileyVariant;
    use crate::workloads::{hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant};

    #[test]
    fn proportional_conserves_and_floors() {
        let a = proportional(10, &[1.0, 3.0, 6.0], &[false, false, false]);
        assert_eq!(a.iter().sum::<usize>(), 10);
        assert!(a.iter().all(|&x| x >= 1));
        assert!(a[2] > a[1] && a[1] > a[0], "{a:?}");
    }

    #[test]
    fn proportional_pins_serial() {
        let a = proportional(10, &[100.0, 1.0], &[true, false]);
        assert_eq!(a[0], 1);
        assert_eq!(a[1], 9);
    }

    #[test]
    fn hyena_maps_single_section() {
        let cfg = RduConfig::fft_mode();
        let g = hyena_decoder(&DecoderConfig::paper(1 << 18), BaileyVariant::Vector);
        let m = map_graph(&g, &cfg).unwrap();
        assert_eq!(m.sections.len(), 1, "256K Hyena fits on-chip");
        assert!(m.max_pcus_used() <= cfg.spec.n_pcu);
    }

    #[test]
    fn allocation_never_exceeds_chip() {
        for cfg in [RduConfig::baseline(), RduConfig::fft_mode(), RduConfig::b_scan_mode()] {
            for dc in DecoderConfig::paper_sweep() {
                let g = hyena_decoder(&dc, BaileyVariant::Vector);
                let m = map_graph(&g, &cfg).unwrap();
                for s in &m.sections {
                    assert!(s.allocs.iter().map(|a| a.pcus).sum::<usize>() <= cfg.spec.n_pcu);
                    assert!(s.allocs.iter().map(|a| a.pmus).sum::<usize>() <= cfg.spec.n_pmu);
                    assert!(s.resident_bytes <= cfg.spec.sram_bytes() as f64);
                }
            }
        }
    }

    #[test]
    fn heaviest_kernel_gets_most_pcus() {
        let cfg = RduConfig::baseline();
        let g = hyena_decoder(&DecoderConfig::paper(1 << 20), BaileyVariant::Vector);
        let m = map_graph(&g, &cfg).unwrap();
        // The serialized vector-FFT kernels dominate demand on the baseline.
        for s in &m.sections {
            let (max_alloc_id, _) = s
                .allocs
                .iter()
                .map(|a| (a.kernel, a.pcus))
                .max_by_key(|&(_, p)| p)
                .unwrap();
            let name = &g.kernels[max_alloc_id].name;
            assert!(name.contains("fft"), "heaviest = {name}");
        }
    }

    #[test]
    fn serial_scan_pinned_to_one_pcu() {
        let cfg = RduConfig::baseline();
        let g = mamba_decoder(&DecoderConfig::paper(1 << 18), ScanVariant::CScan);
        let m = map_graph(&g, &cfg).unwrap();
        let scan_id = g.kernels.iter().position(|k| k.name == "selective_scan").unwrap();
        let alloc = m
            .sections
            .iter()
            .flat_map(|s| &s.allocs)
            .find(|a| a.kernel == scan_id)
            .unwrap();
        assert_eq!(alloc.pcus, 1);
    }

    #[test]
    fn empty_graph_rejected() {
        let g = Graph::new("empty");
        assert_eq!(map_graph(&g, &RduConfig::baseline()), Err(MapFailure::EmptyGraph));
        assert_eq!(map_graph_plan(&g, &RduConfig::baseline(), &[]), Err(MapFailure::EmptyGraph));
    }

    #[test]
    fn oversized_kernel_rejected_by_name() {
        use crate::graph::{Kernel, OpClass};
        let cfg = RduConfig::baseline();
        let sram = cfg.spec.sram_bytes() as f64;
        let mut g = Graph::new("huge");
        // A kernel whose resident weights alone exceed total chip SRAM.
        let k = g.add(
            Kernel::new("giant_embedding", OpClass::Gemm, 1.0, 1.0, 1.0).with_weights(2.0 * sram),
        );
        g.input(k, 1.0);
        g.output(k, 1.0);
        let err = map_graph(&g, &cfg).unwrap_err();
        match &err {
            MapFailure::KernelTooLarge { kernel, name, bytes, sram: s } => {
                assert_eq!(*kernel, k);
                assert_eq!(name, "giant_embedding");
                assert!(*bytes > *s);
            }
            other => panic!("expected KernelTooLarge, got {other:?}"),
        }
        // The failure message names the offending kernel.
        let msg = err.to_string();
        assert!(msg.contains("giant_embedding"), "{msg}");
        // The plan-driven mapper fails identically.
        let err2 = map_graph_plan(&g, &cfg, &[vec![k]]).unwrap_err();
        assert_eq!(err, err2);
    }

    #[test]
    fn plan_mapping_sections_follow_clusters() {
        let cfg = RduConfig::fft_mode();
        let g = hyena_decoder(&DecoderConfig::paper(1 << 14), BaileyVariant::Vector);
        let n = g.kernels.len();
        // Kernel-by-kernel plan: one section per kernel, whole chip each.
        let singles: Vec<Vec<usize>> = g.topo_order().into_iter().map(|i| vec![i]).collect();
        let m = map_graph_plan(&g, &cfg, &singles).unwrap();
        assert_eq!(m.sections.len(), n);
        for s in &m.sections {
            assert_eq!(s.kernels.len(), 1);
            let a = &s.allocs[0];
            // A lone divisible kernel gets every PCU on the chip.
            if !is_serial(&g.kernels[a.kernel]) {
                assert_eq!(a.pcus, cfg.spec.n_pcu);
            } else {
                assert_eq!(a.pcus, 1);
            }
        }
        // A two-cluster plan yields two sections in the given order.
        let order = g.topo_order();
        let (left, right) = order.split_at(order.len() / 2);
        let m2 = map_graph_plan(&g, &cfg, &[left.to_vec(), right.to_vec()]).unwrap();
        assert_eq!(m2.sections.len(), 2);
        assert_eq!(m2.sections[0].kernels, left);
        assert_eq!(m2.sections[1].kernels, right);
    }
}
