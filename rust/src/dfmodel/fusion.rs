//! Fusion pass for the dataflow mapper: cluster producer→consumer *stream*
//! chains (FFT → elementwise → iFFT, scan → gate → proj, the MLP spine)
//! into single spatially-mapped sections whose intermediate tensors stay in
//! PCU/PMU SRAM instead of round-tripping DRAM between kernel launches.
//!
//! The pass is a scheduling transform, not a numerics transform: a fused
//! cluster executes exactly the kernels of its members, in the same
//! dataflow order, as one pipelined spatial program (validated bit-exactly
//! by the fused PCU programs in [`crate::pcusim::programs`]). What changes
//! is the *launch granularity* the performance model prices:
//!
//! * **unfused** ([`FusionPlan::unfused`]) — every kernel is its own
//!   section: one fabric configuration per kernel, every intermediate
//!   tensor written to and re-read from DRAM (paper Fig. 1C,
//!   kernel-by-kernel execution);
//! * **fused** ([`fuse_graph`]) — clusters grown greedily along stream
//!   edges, so a section's off-chip traffic drops to its streamed chain's
//!   first input plus last output and its member kernels overlap as
//!   pipeline stages. Buffered side operands (gating branches, residual
//!   skips) still round-trip DRAM even inside a cluster — the capacity
//!   model charges only per-kernel tiles, so claiming SRAM residency for
//!   whole held tensors would be unpaid-for (see [`FusionPlan::edge_fused`]).
//!
//! Cluster growth obeys three legality rules, checked per candidate merge:
//!
//! 1. **streamability** — a kernel only joins the cluster(s) of its
//!    stream-edge producers ([`crate::graph::Edge::stream`]);
//! 2. **capacity** — the merged cluster's resident bytes (weights +
//!    corner-turn buffers + stream tiles, [`super::mapping::resident_bytes`])
//!    fit in chip SRAM, and its kernel count fits the PCU budget;
//! 3. **convexity** — the merge must keep the cluster quotient graph
//!    acyclic, otherwise the fused sections could not be scheduled
//!    back-to-back.
//!
//! [`super::perf::estimate_fused`] / [`super::perf::estimate_unfused`]
//! price the resulting plans; `simulate --fuse`, the `fusion` bench and
//! `figures::fusion` report the end-to-end win.

use super::mapping::resident_bytes;
use crate::arch::RduConfig;
use crate::graph::{Graph, KernelId};

/// A partition of a graph's kernels into fusion clusters.
#[derive(Debug, Clone, PartialEq)]
pub struct FusionPlan {
    /// Kernel clusters in a valid topological order; each becomes one
    /// section (one spatial program) of the mapping.
    pub clusters: Vec<Vec<KernelId>>,
    /// For every kernel, the index of its cluster in `clusters`.
    pub cluster_of: Vec<usize>,
}

impl FusionPlan {
    /// The kernel-by-kernel plan: every kernel its own cluster, in
    /// topological order — the unfused baseline the fusion win is measured
    /// against.
    pub fn unfused(g: &Graph) -> Self {
        let order = g.topo_order();
        let mut cluster_of = vec![0usize; g.kernels.len()];
        for (c, &k) in order.iter().enumerate() {
            cluster_of[k] = c;
        }
        Self { clusters: order.into_iter().map(|k| vec![k]).collect(), cluster_of }
    }

    /// Is edge `e` fused — i.e. a *stream* edge whose endpoints share a
    /// cluster, so its tensor flows producer→consumer through SRAM tiles
    /// and never touches DRAM?
    ///
    /// Deliberately restricted to stream edges: a buffered intra-cluster
    /// edge (a gating second operand, a residual skip) must hold its whole
    /// tensor while the pipeline drains, and the capacity model only
    /// charges per-kernel tiles — so those edges keep paying the DRAM
    /// round-trip rather than claiming SRAM residency the capacity check
    /// never accounted for.
    pub fn edge_fused(&self, g: &Graph, e: usize) -> bool {
        let edge = &g.edges[e];
        match (edge.src, edge.dst) {
            (Some(s), Some(d)) => edge.stream && self.cluster_of[s] == self.cluster_of[d],
            _ => false,
        }
    }

    /// Bytes of intermediate tensors kept on-chip by this plan.
    pub fn fused_intermediate_bytes(&self, g: &Graph) -> f64 {
        (0..g.edges.len())
            .filter(|&e| self.edge_fused(g, e))
            .map(|e| g.edges[e].bytes)
            .sum()
    }

    /// Bytes of intermediate tensors staged through DRAM by this plan —
    /// every internal edge that crosses a cluster boundary.
    pub fn staged_intermediate_bytes(&self, g: &Graph) -> f64 {
        g.intermediate_bytes() - self.fused_intermediate_bytes(g)
    }

    /// Number of fabric configurations (spatial-program launches) the plan
    /// requires per forward pass.
    pub fn launches(&self) -> usize {
        self.clusters.len()
    }
}

/// Would assigning kernel `k` to cluster `target` — after merging every
/// cluster in `merge` into `target` — keep the cluster quotient graph
/// acyclic? `assign[i]` holds the current cluster of kernel `i`
/// (`usize::MAX` = unassigned; unassigned kernels other than `k` are
/// ignored, which is safe because clusters only ever contain kernels that
/// precede `k` in topological order).
fn merge_keeps_acyclic(
    g: &Graph,
    assign: &[usize],
    merge: &[usize],
    target: usize,
    k: KernelId,
    n_clusters: usize,
) -> bool {
    let resolve = |kernel: KernelId| -> Option<usize> {
        if kernel == k {
            return Some(target);
        }
        match assign[kernel] {
            usize::MAX => None,
            c if merge.contains(&c) => Some(target),
            c => Some(c),
        }
    };
    // Kahn's algorithm over the quotient graph.
    let mut indeg = vec![0usize; n_clusters];
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n_clusters];
    for e in &g.edges {
        if let (Some(s), Some(d)) = (e.src, e.dst) {
            if let (Some(cs), Some(cd)) = (resolve(s), resolve(d)) {
                if cs != cd {
                    succ[cs].push(cd);
                    indeg[cd] += 1;
                }
            }
        }
    }
    let mut ready: Vec<usize> = (0..n_clusters).filter(|&c| indeg[c] == 0).collect();
    let mut seen = 0usize;
    while let Some(c) = ready.pop() {
        seen += 1;
        for &d in &succ[c] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                ready.push(d);
            }
        }
    }
    seen == n_clusters
}

/// Greedily cluster `g`'s fusable stream chains for `cfg`.
///
/// Kernels are visited in topological order; each kernel tries to join the
/// merged cluster of *all* its stream-edge producers (so a two-input
/// elementwise stage like Hyena's frequency-domain multiply pulls both
/// forward-FFT clusters together). A merge that would breach SRAM, exceed
/// the chip's PCU count, or create a cycle between clusters is declined and
/// the kernel starts its own cluster — at long sequence lengths this is
/// what splits the Hyena conv pipeline when six corner-turn buffers no
/// longer co-reside.
pub fn fuse_graph(g: &Graph, cfg: &RduConfig) -> FusionPlan {
    let n = g.kernels.len();
    let sram = cfg.spec.sram_bytes() as f64;
    let res: Vec<f64> = (0..n).map(|i| resident_bytes(g, i, cfg)).collect();

    // Growing state: cluster member lists (never reordered — members are
    // appended in topological order) plus per-cluster byte totals.
    let mut members: Vec<Vec<KernelId>> = Vec::new();
    let mut bytes: Vec<f64> = Vec::new();
    let mut assign = vec![usize::MAX; n];

    for &k in &g.topo_order() {
        let mut cands: Vec<usize> =
            g.stream_predecessors(k).iter().map(|&p| assign[p]).collect();
        cands.sort_unstable();
        cands.dedup();

        let joined = if cands.is_empty() {
            false
        } else {
            let target = cands[0];
            let merged_bytes: f64 = res[k] + cands.iter().map(|&c| bytes[c]).sum::<f64>();
            let merged_len: usize = 1 + cands.iter().map(|&c| members[c].len()).sum::<usize>();
            merged_bytes <= sram
                && merged_len <= cfg.spec.n_pcu
                && merge_keeps_acyclic(g, &assign, &cands[1..], target, k, members.len())
        };

        if joined {
            let target = cands[0];
            // Fold the other candidate clusters into `target`, preserving
            // each member list's topological order (later clusters hold
            // later kernels is *not* guaranteed across merged chains, but
            // within-section order is irrelevant to the pipelined model).
            for &c in &cands[1..] {
                let moved = std::mem::take(&mut members[c]);
                for &m in &moved {
                    assign[m] = target;
                }
                members[target].extend(moved);
                bytes[target] += std::mem::replace(&mut bytes[c], 0.0);
            }
            members[target].push(k);
            bytes[target] += res[k];
            assign[k] = target;
        } else {
            assign[k] = members.len();
            members.push(vec![k]);
            bytes.push(res[k]);
        }
    }

    // Drop emptied clusters and order the survivors topologically so the
    // mapper can schedule the sections back-to-back.
    let live: Vec<usize> = (0..members.len()).filter(|&c| !members[c].is_empty()).collect();
    let index_of = |c: usize| live.iter().position(|&x| x == c).expect("live cluster");
    let m = live.len();
    let mut indeg = vec![0usize; m];
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); m];
    for e in &g.edges {
        if let (Some(s), Some(d)) = (e.src, e.dst) {
            let (cs, cd) = (index_of(assign[s]), index_of(assign[d]));
            if cs != cd {
                succ[cs].push(cd);
                indeg[cd] += 1;
            }
        }
    }
    let mut ready: Vec<usize> = (0..m).filter(|&c| indeg[c] == 0).collect();
    let mut topo = Vec::with_capacity(m);
    while let Some(c) = ready.pop() {
        topo.push(c);
        for &d in &succ[c] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                ready.push(d);
            }
        }
    }
    assert_eq!(topo.len(), m, "fusion produced a cyclic cluster graph");

    let mut clusters = Vec::with_capacity(m);
    let mut cluster_of = vec![0usize; n];
    for (pos, &c) in topo.iter().enumerate() {
        let ids = std::mem::take(&mut members[live[c]]);
        for &k in &ids {
            cluster_of[k] = pos;
        }
        clusters.push(ids);
    }
    FusionPlan { clusters, cluster_of }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::BaileyVariant;
    use crate::graph::{Kernel, OpClass};
    use crate::workloads::{hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant};

    fn cfg() -> RduConfig {
        RduConfig::fft_mode()
    }

    #[test]
    fn unfused_plan_is_kernel_by_kernel() {
        let g = hyena_decoder(&DecoderConfig::paper(1 << 12), BaileyVariant::Vector);
        let p = FusionPlan::unfused(&g);
        assert_eq!(p.launches(), g.kernels.len());
        assert_eq!(p.fused_intermediate_bytes(&g), 0.0);
        assert!((p.staged_intermediate_bytes(&g) - g.intermediate_bytes()).abs() < 1e-6);
    }

    #[test]
    fn fusion_covers_all_kernels_exactly_once() {
        for g in [
            hyena_decoder(&DecoderConfig::paper(1 << 12), BaileyVariant::Vector),
            mamba_decoder(&DecoderConfig::paper(1 << 12), ScanVariant::Parallel),
        ] {
            let p = fuse_graph(&g, &cfg());
            let mut seen = vec![false; g.kernels.len()];
            for (ci, c) in p.clusters.iter().enumerate() {
                assert!(!c.is_empty());
                for &k in c {
                    assert!(!seen[k], "kernel {k} in two clusters");
                    seen[k] = true;
                    assert_eq!(p.cluster_of[k], ci);
                }
            }
            assert!(seen.iter().all(|&s| s), "{}", g.name);
        }
    }

    #[test]
    fn clusters_are_topologically_ordered_and_acyclic() {
        let g = hyena_decoder(&DecoderConfig::paper(1 << 14), BaileyVariant::Vector);
        let p = fuse_graph(&g, &cfg());
        for e in &g.edges {
            if let (Some(s), Some(d)) = (e.src, e.dst) {
                assert!(
                    p.cluster_of[s] <= p.cluster_of[d],
                    "edge {s}->{d} goes backwards across clusters"
                );
            }
        }
    }

    #[test]
    fn hyena_fft_conv_chains_fuse() {
        // The issue's headline chain: FFT → freq-multiply → iFFT must land
        // in one cluster (the freqmul stage pulls both forward FFTs in).
        let g = hyena_decoder(&DecoderConfig::paper(1 << 12), BaileyVariant::Vector);
        let p = fuse_graph(&g, &cfg());
        let id = |name: &str| g.kernels.iter().position(|k| k.name == name).unwrap();
        for tag in ["conv1", "conv2"] {
            let c = p.cluster_of[id(&format!("{tag}.fft_x"))];
            assert_eq!(c, p.cluster_of[id(&format!("{tag}.fft_k"))], "{tag}");
            assert_eq!(c, p.cluster_of[id(&format!("{tag}.freqmul"))], "{tag}");
            assert_eq!(c, p.cluster_of[id(&format!("{tag}.ifft"))], "{tag}");
        }
        assert!(p.launches() < g.kernels.len() / 2, "{} launches", p.launches());
    }

    #[test]
    fn mamba_scan_gate_proj_chain_fuses() {
        let g = mamba_decoder(&DecoderConfig::paper(1 << 12), ScanVariant::Parallel);
        let p = fuse_graph(&g, &cfg());
        let id = |name: &str| g.kernels.iter().position(|k| k.name == name).unwrap();
        let c = p.cluster_of[id("selective_scan")];
        assert_eq!(c, p.cluster_of[id("c_contract")]);
        assert_eq!(c, p.cluster_of[id("gate.z")]);
        assert_eq!(c, p.cluster_of[id("out_proj")]);
    }

    #[test]
    fn fused_plus_staged_equals_intermediates() {
        let g = mamba_decoder(&DecoderConfig::paper(1 << 14), ScanVariant::CScan);
        let p = fuse_graph(&g, &cfg());
        let total = p.fused_intermediate_bytes(&g) + p.staged_intermediate_bytes(&g);
        assert!((total - g.intermediate_bytes()).abs() / total < 1e-12);
        assert!(p.fused_intermediate_bytes(&g) > 0.0, "something must fuse");
    }

    #[test]
    fn capacity_limits_split_clusters_at_long_l() {
        // At 1M tokens the six FFT corner-turn buffers cannot co-reside in
        // 780 MB of SRAM, so the conv pipeline must split — but every
        // cluster must still fit.
        let g = hyena_decoder(&DecoderConfig::paper(1 << 20), BaileyVariant::Vector);
        let c = cfg();
        let p = fuse_graph(&g, &c);
        let sram = c.spec.sram_bytes() as f64;
        for cl in &p.clusters {
            let b: f64 = cl.iter().map(|&k| super::resident_bytes(&g, k, &c)).sum();
            assert!(b <= sram, "cluster over SRAM: {b}");
        }
        let small_graph = hyena_decoder(&DecoderConfig::paper(1 << 12), BaileyVariant::Vector);
        let small = fuse_graph(&small_graph, &c);
        assert!(p.launches() > small.launches(), "long L must section more");
    }

    #[test]
    fn no_stream_edges_means_no_fusion() {
        let mut g = Graph::new("plain");
        let a = g.add(Kernel::new("a", OpClass::Gemm, 10.0, 1.0, 1.0));
        let b = g.add(Kernel::new("b", OpClass::Gemm, 10.0, 1.0, 1.0));
        g.input(a, 1.0);
        g.connect(a, b, 1.0); // non-stream
        g.output(b, 1.0);
        let p = fuse_graph(&g, &cfg());
        assert_eq!(p.launches(), 2);
        assert_eq!(p.fused_intermediate_bytes(&g), 0.0);
    }

    #[test]
    fn stream_chain_fuses_into_one_cluster() {
        let mut g = Graph::new("chain");
        let a = g.add(Kernel::new("a", OpClass::Gemm, 10.0, 1.0, 1.0));
        let b = g.add(Kernel::new("b", OpClass::Elementwise, 10.0, 1.0, 1.0));
        let c = g.add(Kernel::new("c", OpClass::Gemm, 10.0, 1.0, 1.0));
        g.input(a, 1.0);
        g.connect_stream(a, b, 1.0);
        g.connect_stream(b, c, 1.0);
        g.output(c, 1.0);
        let p = fuse_graph(&g, &cfg());
        assert_eq!(p.launches(), 1);
        assert_eq!(p.clusters[0], vec![a, b, c]);
        assert_eq!(p.staged_intermediate_bytes(&g), 0.0);
    }

    #[test]
    fn diamond_with_side_path_stays_acyclic() {
        // a →(stream) b → (stream) d, a →(plain) c →(stream) d: merging d
        // with {a,b} and {c} must not create a cycle; the pass may merge
        // them all (c's only in-edge is from a's cluster, which is fine) —
        // whatever it picks, the quotient graph must stay a DAG.
        let mut g = Graph::new("diamond");
        let a = g.add(Kernel::new("a", OpClass::Gemm, 1.0, 1.0, 1.0));
        let b = g.add(Kernel::new("b", OpClass::Elementwise, 1.0, 1.0, 1.0));
        let c = g.add(Kernel::new("c", OpClass::Elementwise, 1.0, 1.0, 1.0));
        let d = g.add(Kernel::new("d", OpClass::Gemm, 1.0, 1.0, 1.0));
        g.input(a, 1.0);
        g.connect_stream(a, b, 1.0);
        g.connect(a, c, 1.0);
        g.connect_stream(b, d, 1.0);
        g.connect_stream(c, d, 1.0);
        g.output(d, 1.0);
        let p = fuse_graph(&g, &cfg());
        for e in &g.edges {
            if let (Some(s), Some(dd)) = (e.src, e.dst) {
                assert!(p.cluster_of[s] <= p.cluster_of[dd]);
            }
        }
    }
}
