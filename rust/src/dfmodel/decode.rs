//! Decode-step cost hook: modeled cycles/seconds for one token of one
//! session on an RDU configuration — the number the continuous-batching
//! scheduler and the session simulation driver use to attach hardware time
//! to iteration batches without a PJRT backend.
//!
//! Decode is the recurrence phase (paper §II-B): per token each layer does
//! a handful of GEMVs plus the state update, so per-step arithmetic is
//! O(1) in sequence length — exactly why SSMs win long-sequence serving.
//! Decoder weights are assumed SRAM-resident (at the paper's D = 32 they
//! are a rounding error against 780 MB of PMU SRAM), so the memory
//! component is state + per-token activation traffic; off-chip *spill*
//! traffic is accounted separately by the session state cache.

use crate::arch::{InterchipLink, RduConfig};
use crate::runtime::ModelKind;
use crate::workloads::{family_workload, DecoderConfig, Workload};

/// Effective FLOP utilization of decode-step kernels: GEMV-shaped work
/// cannot saturate the systolic datapaths the way prefill GEMMs do.
pub const DECODE_UTIL: f64 = 0.25;

/// Modeled cost of one decode step (one token, one session, all layers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeCost {
    /// Arithmetic work of the step.
    pub flops: f64,
    /// Recurrent-state bytes touched (read + write), all layers.
    pub state_bytes: f64,
    /// Total memory traffic of the step (state + token activations).
    pub io_bytes: f64,
    pub compute_seconds: f64,
    pub memory_seconds: f64,
    /// Step latency: max(compute, memory) — the streams overlap under
    /// dataflow execution, same as [`super::perf`].
    pub seconds: f64,
    /// Step latency in chip clock cycles.
    pub cycles: f64,
}

/// Model one decode step of `layers` decoder layers shaped by `dc` on `cfg`
/// for a serving-stack family — resolves the family's canonical workload in
/// the registry and defers to [`decode_step_workload`]. The per-model
/// demand formulas live with the workloads
/// ([`crate::workloads::Workload::decode_demand`]), not here.
pub fn decode_step(
    model: ModelKind,
    dc: &DecoderConfig,
    layers: usize,
    cfg: &RduConfig,
) -> DecodeCost {
    decode_step_workload(family_workload(model), dc, layers, cfg)
}

/// Model one decode step for any registered workload: the workload supplies
/// its token-mixer demand, this hook adds the template-shared MLP GEMVs and
/// per-token I/O and prices the overlapped step.
pub fn decode_step_workload(
    w: &dyn Workload,
    dc: &DecoderConfig,
    layers: usize,
    cfg: &RduConfig,
) -> DecodeCost {
    let d = dc.d_model as f64;
    // Two MLP GEMVs (d → mlp·d → d), 2 FLOPs per MAC.
    let mlp_flops = 4.0 * d * (dc.mlp_mult as f64) * d;
    let demand = w.decode_demand(dc);
    let l = layers.max(1) as f64;
    let flops = l * (demand.mix_flops + mlp_flops);
    let state = l * demand.state_bytes;
    // One token in, one token out per layer boundary.
    let io_bytes = state + l * 2.0 * d * dc.dtype_bytes;
    cost_from(flops, state, io_bytes, cfg)
}

/// Derive the overlapped step cost from raw flop/byte demands — the single
/// place the decode cost rules (utilization, overlap, cycles) live, shared
/// by the full and chips-partitioned steps.
fn cost_from(flops: f64, state_bytes: f64, io_bytes: f64, cfg: &RduConfig) -> DecodeCost {
    let compute_seconds = flops / (cfg.spec.peak_flops() * DECODE_UTIL);
    let memory_seconds = io_bytes / cfg.spec.dram_bandwidth();
    let seconds = compute_seconds.max(memory_seconds);
    DecodeCost {
        flops,
        state_bytes,
        io_bytes,
        compute_seconds,
        memory_seconds,
        seconds,
        cycles: seconds * cfg.spec.clock_hz,
    }
}

/// Spatial-program launches of one decoder layer's per-token graph under
/// kernel-by-kernel execution — the launches a *fused*, fabric-resident
/// decode pipeline amortizes away entirely (the configuration stays loaded
/// between tokens, so [`decode_step`] pays none of them).
pub const DECODE_KERNELS_PER_LAYER: f64 = 10.0;

/// Modeled cost of one decode step executed kernel-by-kernel (unfused):
/// each of the layer's ~[`DECODE_KERNELS_PER_LAYER`] kernels launches
/// separately, paying a fabric reconfiguration, and the inter-kernel
/// activation vectors round-trip DRAM instead of streaming PCU→PCU.
///
/// [`decode_step`] is the fused counterpart (and the default everywhere the
/// session scheduler attaches hardware time): the per-token pipeline stays
/// resident on the fabric, so only state + token I/O touch memory.
pub fn decode_step_unfused(
    model: ModelKind,
    dc: &DecoderConfig,
    layers: usize,
    cfg: &RduConfig,
) -> DecodeCost {
    let fused = decode_step(model, dc, layers, cfg);
    let l = layers.max(1) as f64;
    let widest = dc.d_model.max(dc.d_inner()) as f64;
    // Each inter-kernel boundary stages one activation vector of the
    // layer's widest width: one DRAM write + one read.
    let staged = l * (DECODE_KERNELS_PER_LAYER - 1.0) * 2.0 * widest * dc.dtype_bytes;
    let launches = l * DECODE_KERNELS_PER_LAYER;
    let mut c = cost_from(fused.flops, fused.state_bytes, fused.io_bytes + staged, cfg);
    c.compute_seconds += launches * super::throughput::reconfig_seconds(cfg);
    c.seconds = c.compute_seconds.max(c.memory_seconds);
    c.cycles = c.seconds * cfg.spec.clock_hz;
    c
}

/// Modeled cost of one decode step sharded over `chips` chips.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardedDecodeCost {
    /// One chip's share of the step (flops / state / io divided by `chips`).
    pub per_chip: DecodeCost,
    /// Per-step inter-chip exchange: one ring all-reduce of the `d_model`
    /// token activation per layer.
    pub comm_seconds: f64,
    /// Step latency: per-chip step + exchange (the all-reduce is a barrier
    /// between layers, so it does not overlap the chip-local work).
    pub seconds: f64,
    pub chips: usize,
}

/// Model one decode step with the per-token state and arithmetic
/// partitioned across `chips` chips (tensor-style channel split: each chip
/// owns `1/chips` of the recurrent state, and the `d_model` activation is
/// ring-allreduced once per layer over `link`).
pub fn decode_step_sharded(
    model: ModelKind,
    dc: &DecoderConfig,
    layers: usize,
    cfg: &RduConfig,
    chips: usize,
    link: &InterchipLink,
) -> ShardedDecodeCost {
    let chips = chips.max(1);
    let full = decode_step(model, dc, layers, cfg);
    let p = chips as f64;
    let per_chip = cost_from(full.flops / p, full.state_bytes / p, full.io_bytes / p, cfg);
    let comm_seconds = layers.max(1) as f64
        * link.ring_allreduce_seconds(chips, dc.d_model as f64 * dc.dtype_bytes);
    ShardedDecodeCost { per_chip, comm_seconds, seconds: per_chip.seconds + comm_seconds, chips }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_are_positive_and_consistent() {
        let dc = DecoderConfig::paper(1 << 20);
        let cfg = RduConfig::hs_scan_mode();
        for model in ModelKind::ALL {
            let c = decode_step(model, &dc, 8, &cfg);
            assert!(c.flops > 0.0, "{model}");
            assert!(c.seconds > 0.0, "{model}");
            assert!(c.seconds >= c.compute_seconds && c.seconds >= c.memory_seconds);
            assert!((c.cycles - c.seconds * cfg.spec.clock_hz).abs() < 1e-6);
        }
    }

    #[test]
    fn cost_scales_with_layers() {
        let dc = DecoderConfig::paper(1 << 20);
        let cfg = RduConfig::baseline();
        let one = decode_step(ModelKind::Mamba, &dc, 1, &cfg);
        let eight = decode_step(ModelKind::Mamba, &dc, 8, &cfg);
        assert!((eight.flops / one.flops - 8.0).abs() < 1e-9);
        assert!(eight.seconds >= one.seconds);
    }

    #[test]
    fn mamba_state_grows_with_state_dim() {
        let cfg = RduConfig::baseline();
        let small = decode_step(ModelKind::Mamba, &DecoderConfig::paper(1 << 20), 4, &cfg);
        let full = decode_step(ModelKind::Mamba, &DecoderConfig::mamba_full(1 << 20), 4, &cfg);
        assert!(full.state_bytes > small.state_bytes, "N=16,E=2 touches more state");
        assert!(full.flops > small.flops);
    }

    #[test]
    fn decode_step_is_independent_of_seq_len() {
        // The whole point of SSM decode: O(1) per-token cost.
        let cfg = RduConfig::hs_scan_mode();
        let short = decode_step(ModelKind::Mamba, &DecoderConfig::paper(1 << 10), 8, &cfg);
        let long = decode_step(ModelKind::Mamba, &DecoderConfig::paper(1 << 20), 8, &cfg);
        assert_eq!(short, long);
    }

    #[test]
    fn unfused_decode_strictly_slower() {
        // The fused (fabric-resident) decode pipeline must beat
        // kernel-by-kernel launches for every model on every config.
        for cfg in [RduConfig::baseline(), RduConfig::hs_scan_mode(), RduConfig::fft_mode()] {
            for model in ModelKind::ALL {
                let dc = DecoderConfig::mamba_full(1 << 16);
                let fused = decode_step(model, &dc, 8, &cfg);
                let unfused = decode_step_unfused(model, &dc, 8, &cfg);
                assert!(
                    unfused.seconds > fused.seconds,
                    "{model} on {}: unfused {} !> fused {}",
                    cfg.name(),
                    unfused.seconds,
                    fused.seconds
                );
                assert!(unfused.io_bytes > fused.io_bytes);
                assert_eq!(unfused.flops, fused.flops, "fusion changes no arithmetic");
            }
        }
    }

    #[test]
    fn workload_hook_agrees_with_the_family_wrapper() {
        // The ModelKind wrapper and the registry path are the same model.
        let dc = DecoderConfig::mamba_full(1 << 16);
        let cfg = RduConfig::hs_scan_mode();
        let pairs = [
            ("mamba", ModelKind::Mamba),
            ("hyena", ModelKind::Hyena),
            ("attention", ModelKind::Attention),
        ];
        for (name, model) in pairs {
            let w = crate::workloads::lookup(name).unwrap();
            assert_eq!(decode_step_workload(w, &dc, 8, &cfg), decode_step(model, &dc, 8, &cfg));
        }
        // SSD decodes exactly like the selective scan; S4 carries its own
        // diagonal state and differs from Hyena's filter caches.
        let ssd = decode_step_workload(crate::workloads::lookup("ssd").unwrap(), &dc, 8, &cfg);
        assert_eq!(ssd, decode_step(ModelKind::Mamba, &dc, 8, &cfg));
        let s4 = decode_step_workload(crate::workloads::lookup("s4").unwrap(), &dc, 8, &cfg);
        assert!(s4.flops > 0.0 && s4.state_bytes > 0.0);
    }

    #[test]
    fn sharded_single_chip_is_the_plain_step() {
        let dc = DecoderConfig::paper(1 << 20);
        let cfg = RduConfig::hs_scan_mode();
        let link = InterchipLink::rdu_fabric();
        let s = decode_step_sharded(ModelKind::Mamba, &dc, 8, &cfg, 1, &link);
        assert_eq!(s.per_chip, decode_step(ModelKind::Mamba, &dc, 8, &cfg));
        assert_eq!(s.comm_seconds, 0.0);
        assert_eq!(s.seconds, s.per_chip.seconds);
    }

    #[test]
    fn sharded_decode_splits_state_and_pays_allreduce() {
        let dc = DecoderConfig::mamba_full(1 << 20);
        let cfg = RduConfig::hs_scan_mode();
        let link = InterchipLink::rdu_fabric();
        let full = decode_step(ModelKind::Mamba, &dc, 8, &cfg);
        let s = decode_step_sharded(ModelKind::Mamba, &dc, 8, &cfg, 4, &link);
        assert!((s.per_chip.flops - full.flops / 4.0).abs() < 1e-9);
        assert!((s.per_chip.state_bytes - full.state_bytes / 4.0).abs() < 1e-9);
        assert!(s.comm_seconds > 0.0, "per-layer all-reduce is on the wire");
        assert!(s.seconds >= s.per_chip.seconds + s.comm_seconds * 0.999);
        // Per-token decode moves tiny activations: the latency-bound
        // all-reduce dominates, so sharding decode is a *capacity* play
        // (state per chip), not a latency play — the model must show that.
        assert!(s.seconds > full.seconds * 0.999, "chips={} {:?}", s.chips, s);
    }
}
