//! Decode-step cost hook: modeled cycles/seconds for one token of one
//! session on an RDU configuration — the number the continuous-batching
//! scheduler and the session simulation driver use to attach hardware time
//! to iteration batches without a PJRT backend.
//!
//! Decode is the recurrence phase (paper §II-B): per token each layer does
//! a handful of GEMVs plus the state update, so per-step arithmetic is
//! O(1) in sequence length — exactly why SSMs win long-sequence serving.
//! Decoder weights are assumed SRAM-resident (at the paper's D = 32 they
//! are a rounding error against 780 MB of PMU SRAM), so the memory
//! component is state + per-token activation traffic; off-chip *spill*
//! traffic is accounted separately by the session state cache.

use crate::arch::RduConfig;
use crate::runtime::ModelKind;
use crate::workloads::DecoderConfig;

/// Effective FLOP utilization of decode-step kernels: GEMV-shaped work
/// cannot saturate the systolic datapaths the way prefill GEMMs do.
pub const DECODE_UTIL: f64 = 0.25;

/// Modeled cost of one decode step (one token, one session, all layers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeCost {
    /// Arithmetic work of the step.
    pub flops: f64,
    /// Recurrent-state bytes touched (read + write), all layers.
    pub state_bytes: f64,
    /// Total memory traffic of the step (state + token activations).
    pub io_bytes: f64,
    pub compute_seconds: f64,
    pub memory_seconds: f64,
    /// Step latency: max(compute, memory) — the streams overlap under
    /// dataflow execution, same as [`super::perf`].
    pub seconds: f64,
    /// Step latency in chip clock cycles.
    pub cycles: f64,
}

/// Model one decode step of `layers` decoder layers shaped by `dc` on `cfg`.
pub fn decode_step(
    model: ModelKind,
    dc: &DecoderConfig,
    layers: usize,
    cfg: &RduConfig,
) -> DecodeCost {
    let d = dc.d_model as f64;
    let di = dc.d_inner() as f64;
    let n = dc.state_dim.max(1) as f64;
    let r = dc.fft_tile as f64;
    // Two MLP GEMVs (d → mlp·d → d), 2 FLOPs per MAC.
    let mlp_flops = 4.0 * d * (dc.mlp_mult as f64) * d;
    let (mix_flops, state_bytes) = match model {
        // In/out projections (d → 2·d_inner, d_inner → d) + the selective
        // scan update h = Ā h + B̄ x and readout y = C h over N × d_inner
        // state; state is read and written once per step (f32).
        ModelKind::Mamba => (2.0 * (d * 2.0 * di + di * d) + 6.0 * n * di, 2.0 * n * di * 4.0),
        // Three gating projections + the R-tap filter contribution per
        // channel; the FFT filter/prefix caches (R × d complex each) are
        // read and updated once per step.
        ModelKind::Hyena => (2.0 * 3.0 * d * d + 4.0 * r * d, 2.0 * 2.0 * r * d * 4.0),
        // QKV + output projections; the KV cache grows with context and is
        // not O(1) — its traffic is out of scope for the SSM session cache.
        ModelKind::Attention => (2.0 * 4.0 * d * d, 0.0),
    };
    let l = layers.max(1) as f64;
    let flops = l * (mix_flops + mlp_flops);
    let state = l * state_bytes;
    // One token in, one token out per layer boundary.
    let io_bytes = state + l * 2.0 * d * dc.dtype_bytes;
    let compute_seconds = flops / (cfg.spec.peak_flops() * DECODE_UTIL);
    let memory_seconds = io_bytes / cfg.spec.dram_bandwidth();
    let seconds = compute_seconds.max(memory_seconds);
    DecodeCost {
        flops,
        state_bytes: state,
        io_bytes,
        compute_seconds,
        memory_seconds,
        seconds,
        cycles: seconds * cfg.spec.clock_hz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_are_positive_and_consistent() {
        let dc = DecoderConfig::paper(1 << 20);
        let cfg = RduConfig::hs_scan_mode();
        for model in ModelKind::ALL {
            let c = decode_step(model, &dc, 8, &cfg);
            assert!(c.flops > 0.0, "{model}");
            assert!(c.seconds > 0.0, "{model}");
            assert!(c.seconds >= c.compute_seconds && c.seconds >= c.memory_seconds);
            assert!((c.cycles - c.seconds * cfg.spec.clock_hz).abs() < 1e-6);
        }
    }

    #[test]
    fn cost_scales_with_layers() {
        let dc = DecoderConfig::paper(1 << 20);
        let cfg = RduConfig::baseline();
        let one = decode_step(ModelKind::Mamba, &dc, 1, &cfg);
        let eight = decode_step(ModelKind::Mamba, &dc, 8, &cfg);
        assert!((eight.flops / one.flops - 8.0).abs() < 1e-9);
        assert!(eight.seconds >= one.seconds);
    }

    #[test]
    fn mamba_state_grows_with_state_dim() {
        let cfg = RduConfig::baseline();
        let small = decode_step(ModelKind::Mamba, &DecoderConfig::paper(1 << 20), 4, &cfg);
        let full = decode_step(ModelKind::Mamba, &DecoderConfig::mamba_full(1 << 20), 4, &cfg);
        assert!(full.state_bytes > small.state_bytes, "N=16,E=2 touches more state");
        assert!(full.flops > small.flops);
    }

    #[test]
    fn decode_step_is_independent_of_seq_len() {
        // The whole point of SSM decode: O(1) per-token cost.
        let cfg = RduConfig::hs_scan_mode();
        let short = decode_step(ModelKind::Mamba, &DecoderConfig::paper(1 << 10), 8, &cfg);
        let long = decode_step(ModelKind::Mamba, &DecoderConfig::paper(1 << 20), 8, &cfg);
        assert_eq!(short, long);
    }
}
