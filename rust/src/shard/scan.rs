//! Sharded Mamba selective scan: per-chip Blelloch-style local scans with an
//! inter-chip exclusive-prefix **carry exchange**.
//!
//! A first-order linear recurrence `h[t] = a[t]·h[t-1] + b[t]` shards over
//! chips because its lifted form is associative
//! ([`crate::scan::recurrence::combine`]): composing a chip's whole
//! sub-sequence yields one `(A, B)` carry that summarizes it, and an
//! exclusive prefix of the per-chip carries gives every chip the state its
//! sub-sequence starts from. Three phases:
//!
//! ```text
//! phase 1 (parallel)   chip p: local inclusive scan of lifted (a,b) steps
//! phase 2 (exchange)   exclusive prefix of per-chip carries (Blelloch
//!                      up-sweep + down-sweep, 2·⌈log₂P⌉ rounds on the wire)
//! phase 3 (parallel)   chip p: h[t] = S_p[t].a · h_in(p) + S_p[t].b
//! ```
//!
//! where `S_p[t]` is chip p's locally scanned composition up to `t` and
//! `h_in(p)` is the carry-in state. The result is exact against
//! [`crate::scan::mamba_scan_serial`] — the associative regrouping changes
//! only floating-point rounding, not the math — for *any* sequence length
//! (non-power-of-two remainders land in [`super::shard_ranges`]'s balanced
//! partition) and any chip count. Wire cost is priced by
//! [`crate::arch::InterchipLink::prefix_exchange_seconds`].

use super::shard_ranges;
use crate::runtime::WorkerPool;
use crate::scan::recurrence::{combine, LinStep};
use std::ops::Range;

/// The identity of the lifted recurrence: `h → 1·h + 0`.
const IDENTITY: LinStep = LinStep { a: 1.0, b: 0.0 };

/// Phase 1 for one chip: the local inclusive scan of its lifted steps. On
/// the RDU each chip runs this as its tiled B-scan (crate::scan::tiled);
/// here the composition order is identical. Shared by the serial and
/// pooled drivers so they are bit-identical by construction.
fn local_scan(a: &[f64], b: &[f64], r: &Range<usize>) -> Vec<LinStep> {
    let mut acc = IDENTITY;
    a[r.clone()]
        .iter()
        .zip(&b[r.clone()])
        .map(|(&ai, &bi)| {
            acc = combine(acc, LinStep { a: ai, b: bi });
            acc
        })
        .collect()
}

/// Phase 2: the carry exchange — exclusive prefix of per-chip totals.
/// (Numerically order-equivalent to the 2·⌈log₂P⌉-round Blelloch
/// up/down-sweep the interconnect model prices; P is small.)
fn exclusive_carries(locals: &[Vec<LinStep>]) -> Vec<LinStep> {
    let mut carry = IDENTITY;
    locals
        .iter()
        .map(|l| {
            let c = carry;
            if let Some(total) = l.last() {
                carry = combine(carry, *total);
            }
            c
        })
        .collect()
}

/// Phases 1 and 2 of the sharded scan, shared by the plain and gate-fused
/// drivers: per-chip local inclusive scans of the lifted steps plus the
/// exclusive prefix of per-chip carries. `pool` fans phase 1 — the
/// embarrassingly parallel per-chip axis — across worker threads.
fn locals_and_carries(
    a: &[f64],
    b: &[f64],
    chips: usize,
    pool: &WorkerPool,
) -> (Vec<Vec<LinStep>>, Vec<LinStep>) {
    assert_eq!(a.len(), b.len(), "sharded_mamba_scan: a/b length mismatch");
    assert!(chips >= 1, "sharded_mamba_scan: need at least one chip");
    let ranges = shard_ranges(a.len(), chips);
    let locals: Vec<Vec<LinStep>> = {
        let _t = crate::telemetry::span("shard", "scan.local").arg("chips", chips as f64);
        pool.map(chips, |p| local_scan(a, b, &ranges[p]))
    };
    let carry_in = {
        let _t = crate::telemetry::span("shard", "scan.carry_exchange").arg("chips", chips as f64);
        exclusive_carries(&locals)
    };
    // Per-chip attribution: mark each chip's carry-in arrival on its track.
    if crate::telemetry::enabled() {
        for (p, c) in carry_in.iter().enumerate() {
            let track = crate::telemetry::chip_track(p);
            crate::telemetry::name_track(crate::telemetry::PID_HOST, track, format!("chip {p}"));
            crate::telemetry::instant_on("shard", "scan.carry_in", track, "carry_b", c.b);
        }
    }
    (locals, carry_in)
}

/// Evaluate the Mamba recurrence `h[t] = a[t]·h[t-1] + b[t]` from `h0 = 0`
/// sharded over `chips` chips. Exact vs [`crate::scan::mamba_scan_serial`]
/// up to floating-point regrouping; see the module docs for the dataflow.
pub fn sharded_mamba_scan(a: &[f64], b: &[f64], chips: usize) -> Vec<f64> {
    let (locals, carry_in) = locals_and_carries(a, b, chips, &WorkerPool::serial());

    // Phase 3 — per chip, in parallel: apply the carry-in state. From
    // h0 = 0 the carry-in state is just `carry.b`.
    let mut out = Vec::with_capacity(a.len());
    for (l, c) in locals.iter().zip(&carry_in) {
        let h_in = c.b;
        out.extend(l.iter().map(|s| s.a * h_in + s.b));
    }
    out
}

/// [`sharded_mamba_scan`] with phases 1 and 3 — the per-chip parallel
/// phases — fanned across `pool`'s worker threads, mirroring in host
/// compute exactly the axis the hardware parallelizes across chips. The
/// per-chip arithmetic and the phase-2 carry composition are shared with
/// the serial driver, so the output is **bit-identical** to it for any
/// length and chip count (asserted by the integration tests).
pub fn sharded_mamba_scan_pooled(
    a: &[f64],
    b: &[f64],
    chips: usize,
    pool: &WorkerPool,
) -> Vec<f64> {
    let (locals, carry_in) = locals_and_carries(a, b, chips, pool);
    let _t = crate::telemetry::span("shard", "scan.apply").arg("chips", chips as f64);
    let outs: Vec<Vec<f64>> = pool.map(locals.len(), |p| {
        let h_in = carry_in[p].b;
        locals[p].iter().map(|s| s.a * h_in + s.b).collect()
    });
    outs.concat()
}

/// Sharded scan with the SiLU gate **fused into phase 3**: each chip's
/// carry-application pass emits `h[t] · silu(z[t])` directly instead of
/// staging the full `h` buffer and gating it in a second kernel — the
/// multi-chip mirror of the mapper's scan→gate fusion cluster. Because
/// the gate multiplies the very value phase 3 produces, the result is
/// bit-identical to gating [`sharded_mamba_scan`]'s output after the fact
/// (the integration tests assert exact equality, ragged lengths included).
pub fn sharded_scan_gate_fused(a: &[f64], b: &[f64], z: &[f64], chips: usize) -> Vec<f64> {
    assert_eq!(a.len(), z.len(), "sharded_scan_gate_fused: z length mismatch");
    let (locals, carry_in) = locals_and_carries(a, b, chips, &WorkerPool::serial());
    let mut out = Vec::with_capacity(a.len());
    for (l, c) in locals.iter().zip(&carry_in) {
        let h_in = c.b;
        for s in l {
            let zi = z[out.len()];
            out.push((s.a * h_in + s.b) * crate::scan::silu(zi));
        }
    }
    out
}

/// Bytes one carry occupies on the wire: a composed `(A, B)` pair per scan
/// channel (`channels = N × d_inner` for the selective SSM), `dtype_bytes`
/// per scalar.
pub fn carry_exchange_bytes(channels: usize, dtype_bytes: f64) -> f64 {
    channels as f64 * 2.0 * dtype_bytes
}

/// Sharded Mamba-2 **SSD** chunked scan: each chip runs the golden chunked
/// evaluator ([`crate::workloads::ssd_scan_with_carry`]) over its
/// contiguous sub-sequence with `q`-element chunks, and the chip-boundary
/// state rides the same carry exchange as [`sharded_mamba_scan`] — here
/// chained in ring order, which keeps every chip's carry-in the *exact*
/// serial state at its boundary. Combined with the bit-exact per-chip
/// evaluator this makes the whole sharded scan **bit-identical** to
/// [`crate::scan::mamba_scan_serial`] for any length, chunk size and chip
/// count (the integration tests assert exact equality at `--chips 2` and
/// beyond). Wire cost is priced by the same
/// [`crate::arch::InterchipLink::prefix_exchange_seconds`] term the
/// sharded estimates charge.
pub fn sharded_ssd_scan(a: &[f64], b: &[f64], chips: usize, q: usize) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "sharded_ssd_scan: a/b length mismatch");
    assert!(chips >= 1, "sharded_ssd_scan: need at least one chip");
    let _t = crate::telemetry::span("shard", "scan.ssd").arg("chips", chips as f64);
    let mut out = Vec::with_capacity(a.len());
    let mut carry = 0.0;
    for r in shard_ranges(a.len(), chips) {
        if r.is_empty() {
            continue;
        }
        let seg =
            crate::workloads::ssd_scan_with_carry(&a[r.clone()], &b[r], q, carry);
        carry = *seg.last().expect("non-empty shard");
        out.extend(seg);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::mamba_scan_serial;
    use crate::util::{max_abs_diff, XorShift};

    #[test]
    fn matches_serial_across_chip_counts() {
        let mut rng = XorShift::new(61);
        for &n in &[1usize, 2, 7, 64, 100, 1000] {
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            let want = mamba_scan_serial(&a, &b);
            for chips in [1usize, 2, 3, 4, 8] {
                let got = sharded_mamba_scan(&a, &b, chips);
                let d = max_abs_diff(&got, &want);
                assert!(d < 1e-10, "n={n} chips={chips} diff={d}");
            }
        }
    }

    #[test]
    fn single_chip_is_the_local_scan() {
        let a = [0.5, 0.9, 0.2, 0.7];
        let b = [1.0, -1.0, 0.5, 2.0];
        let d = max_abs_diff(&sharded_mamba_scan(&a, &b, 1), &mamba_scan_serial(&a, &b));
        assert!(d < 1e-12);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(sharded_mamba_scan(&[], &[], 4).is_empty());
        let got = sharded_mamba_scan(&[0.5], &[2.0], 8);
        assert_eq!(got, vec![2.0], "more chips than elements");
    }

    #[test]
    fn gate_fused_bit_identical_to_staged_gate() {
        let mut rng = XorShift::new(62);
        for &n in &[1usize, 9, 100, 1000, 1023] {
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            let z = rng.vec(n, -3.0, 3.0);
            for chips in [1usize, 2, 3, 8] {
                let staged: Vec<f64> = sharded_mamba_scan(&a, &b, chips)
                    .iter()
                    .zip(&z)
                    .map(|(&h, &zi)| h * crate::scan::silu(zi))
                    .collect();
                assert_eq!(
                    sharded_scan_gate_fused(&a, &b, &z, chips),
                    staged,
                    "n={n} chips={chips}"
                );
            }
        }
    }

    #[test]
    fn pooled_scan_bit_identical_to_serial() {
        let mut rng = XorShift::new(63);
        let pool = WorkerPool::new(3);
        for &n in &[1usize, 7, 100, 1000, 1023] {
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            for chips in [1usize, 2, 4, 8] {
                assert_eq!(
                    sharded_mamba_scan_pooled(&a, &b, chips, &pool),
                    sharded_mamba_scan(&a, &b, chips),
                    "n={n} chips={chips}: pooling must not change a single bit"
                );
            }
        }
    }

    #[test]
    fn sharded_ssd_scan_bit_identical_to_serial() {
        let mut rng = XorShift::new(64);
        for &n in &[1usize, 9, 100, 1000, 1023] {
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            let want = mamba_scan_serial(&a, &b);
            for chips in [1usize, 2, 3, 8] {
                for q in [1usize, 64, 256] {
                    assert_eq!(
                        sharded_ssd_scan(&a, &b, chips, q),
                        want,
                        "n={n} chips={chips} q={q}: must not differ by a bit"
                    );
                }
            }
        }
    }

    #[test]
    fn carry_bytes_scale_with_channels() {
        // 16 states × 64 channels, fp16: (N·d_inner) pairs of 2 bytes.
        assert_eq!(carry_exchange_bytes(16 * 64, 2.0), 16.0 * 64.0 * 4.0);
    }
}
