//! Multi-chip sequence sharding: partition one long sequence across `P` RDU
//! chips so the paper's spatial dataflows scale past a single die.
//!
//! The paper maps FFT-based (Hyena) and scan-based (Mamba) decoders onto
//! *one* RDU. The roadmap's production target needs more sequence than one
//! chip's SRAM and more throughput than one chip's PCUs, so this module adds
//! the two exact sharded dataflows plus the model that prices them:
//!
//! * [`scan`] — sharded Mamba selective scan: each chip runs the lifted
//!   Blelloch/HS scan over its contiguous sub-sequence, chips exchange
//!   *carries* (composed `(a, b)` pairs) in an inter-chip exclusive prefix,
//!   then apply the carry-in locally. Exact against
//!   [`crate::scan::mamba_scan_serial`] for any length and chip count.
//! * [`fft`] — sharded Bailey FFT: the 4-step `R × C` decomposition with
//!   columns block-owned by chips, one all-to-all **transpose** between the
//!   column-FFT and row-FFT phases. Exact against [`crate::fft::dft()`].
//! * [`estimate`] — sharded DFModel [`crate::dfmodel::Estimate`]s: per-chip
//!   compute from the single-chip mapper at `L / P` plus the
//!   [`crate::arch::InterchipLink`] communication term, and the
//!   strong-scaling sweep behind the `shard_scaling` bench. Since the
//!   workload registry these resolve any [`crate::workloads::Workload`] —
//!   the workload supplies its local graph and [`crate::workloads::ShardComm`]
//!   pattern (Mamba/SSD: carry exchange; Hyena/S4: all-to-all transposes),
//!   this module prices it. [`sharded_ssd_scan`] is the SSD numeric driver,
//!   bit-identical to the serial recurrence.
//!
//! The serving integration (per-chip state caches, sharded dispatch,
//! `--chips` on `serve`/`simulate`) lives in [`crate::coordinator`] and the
//! CLI; see `docs/ARCHITECTURE.md` for the exchange diagrams.
//!
//! Both dataflows also come in `_pooled` variants
//! ([`sharded_mamba_scan_pooled`], [`sharded_bailey_fft_pooled`]) that fan
//! the per-chip parallel phases across a [`crate::runtime::WorkerPool`] —
//! host compute mirroring the chip-level parallelism, bit-identical to the
//! serial drivers.

pub mod estimate;
pub mod fft;
pub mod scan;

pub use estimate::{
    sharded_estimate, sharded_estimate_fused, sharded_estimate_fused_workload,
    sharded_estimate_workload, strong_scaling, strong_scaling_workload, ScalingPoint,
    ShardedEstimate,
};
pub use fft::{sharded_bailey_fft, sharded_bailey_fft_pooled, transpose_bytes};
pub use scan::{
    carry_exchange_bytes, sharded_mamba_scan, sharded_mamba_scan_pooled, sharded_scan_gate_fused,
    sharded_ssd_scan,
};

use std::ops::Range;

/// Contiguous partition of `n` elements over `chips` shards: the first
/// `n % chips` shards take one extra element, so any remainder (including a
/// non-power-of-two one) is spread without padding. Shards past `n` are
/// empty ranges.
pub fn shard_ranges(n: usize, chips: usize) -> Vec<Range<usize>> {
    assert!(chips >= 1, "shard_ranges: need at least one chip");
    let base = n / chips;
    let extra = n % chips;
    let mut out = Vec::with_capacity(chips);
    let mut lo = 0;
    for p in 0..chips {
        let len = base + usize::from(p < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_exactly_once() {
        for &(n, chips) in &[(0usize, 1usize), (7, 1), (8, 4), (10, 4), (3, 8), (1000, 8)] {
            let rs = shard_ranges(n, chips);
            assert_eq!(rs.len(), chips);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next, "contiguous n={n} chips={chips}");
                next = r.end;
            }
            assert_eq!(next, n, "covers all of n={n}");
            // Balanced: lengths differ by at most one.
            let lens: Vec<usize> = rs.iter().map(|r| r.len()).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "balanced {lens:?}");
        }
    }

    #[test]
    fn more_chips_than_elements_leaves_empty_shards() {
        let rs = shard_ranges(3, 8);
        assert_eq!(rs.iter().filter(|r| !r.is_empty()).count(), 3);
        assert_eq!(rs.iter().map(|r| r.len()).sum::<usize>(), 3);
    }
}
