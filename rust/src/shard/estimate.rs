//! Sharded DFModel estimates: price a sequence-sharded decoder on `P` chips
//! as *per-chip compute* (the single-chip mapper at `L / P`) plus the
//! *inter-chip communication term* of the sharded dataflow.
//!
//! Communication per model follows the exchanges in [`super::scan`] and
//! [`super::fft`]:
//!
//! * **Mamba** — one carry exchange per forward pass: a composed `(A, B)`
//!   pair per scan channel moves through the `2·⌈log₂P⌉`-round inter-chip
//!   exclusive prefix ([`InterchipLink::prefix_exchange_seconds`]).
//! * **Hyena** — one all-to-all transpose per FFT transform (6 per decoder
//!   layer: two convolutions × two forward + one inverse), each moving
//!   `(P−1)/P` of the padded `fft_len × D` complex tensor
//!   ([`InterchipLink::all_to_all_seconds`]).
//!
//! [`strong_scaling`] sweeps chip counts and reports speedup over one chip
//! and the communication share — the numbers the `shard_scaling` bench and
//! `simulate --chips` print.

use crate::arch::{prefix_exchange_steps, InterchipLink, RduConfig};
use crate::dfmodel::{estimate, Estimate, MapFailure};
use crate::runtime::ModelKind;
use crate::workloads::{family_workload, DecoderConfig, ShardComm, Workload};

/// A sequence-sharded performance estimate: one chip's DFModel mapping plus
/// the interconnect term.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedEstimate {
    pub model: ModelKind,
    /// Registry name of the sharded workload.
    pub workload: &'static str,
    pub chips: usize,
    /// DFModel estimate of one chip's `L / P` sub-sequence.
    pub per_chip: Estimate,
    /// Inter-chip exchange time (carry exchange / all-to-all transposes).
    pub comm_seconds: f64,
    /// Total bytes crossing the inter-chip fabric per forward pass.
    pub comm_bytes: f64,
    /// End-to-end latency: per-chip compute + exchange (the exchange is a
    /// barrier between the sharded phases, so it does not overlap).
    pub total_seconds: f64,
}

impl ShardedEstimate {
    /// Fraction of the total latency spent on the interconnect.
    pub fn comm_share(&self) -> f64 {
        if self.total_seconds <= 0.0 {
            return 0.0;
        }
        self.comm_seconds / self.total_seconds
    }

    /// Cycle attribution of the sharded estimate: the per-chip breakdown
    /// (compute / reconfig / DRAM) with the inter-chip exchange filled in.
    pub fn attribution(&self) -> crate::dfmodel::Attribution {
        let mut a = self.per_chip.attribution();
        a.interchip_seconds = self.comm_seconds;
        a
    }
}

/// One row of a strong-scaling sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingPoint {
    pub est: ShardedEstimate,
    /// Speedup over the single-chip latency at the same total `L`.
    pub speedup: f64,
}

/// Estimate `model`'s canonical registry workload at full sequence length
/// `dc.seq_len` sharded over `chips` chips — the ModelKind-keyed wrapper
/// the serving stack calls; see [`sharded_estimate_workload`].
pub fn sharded_estimate(
    model: ModelKind,
    dc: &DecoderConfig,
    chips: usize,
    cfg: &RduConfig,
    link: &InterchipLink,
) -> Result<ShardedEstimate, MapFailure> {
    sharded_estimate_workload(family_workload(model), dc, chips, cfg, link)
}

/// Estimate any registered workload at full sequence length `dc.seq_len`
/// sharded over `chips` chips of configuration `cfg`, exchanging over
/// `link`. The workload supplies its local graph
/// ([`Workload::shard_local_graph`]) and exchange pattern
/// ([`Workload::shard_comm`]); this function prices them.
///
/// `chips` must divide `dc.seq_len` (the figure sweeps use powers of two).
/// Workloads with [`ShardComm::Unsupported`] (attention) are rejected:
/// quadratic token mixing has no sequence-local phase to shard this way.
pub fn sharded_estimate_workload(
    w: &dyn Workload,
    dc: &DecoderConfig,
    chips: usize,
    cfg: &RduConfig,
    link: &InterchipLink,
) -> Result<ShardedEstimate, MapFailure> {
    let (graph, comm_bytes, comm_seconds) = sharded_graph_and_comm(w, dc, chips, link);
    let per_chip = estimate(&graph, cfg)?;
    Ok(ShardedEstimate {
        model: w.family(),
        workload: w.name(),
        chips,
        comm_seconds,
        comm_bytes,
        total_seconds: per_chip.total_seconds + comm_seconds,
        per_chip,
    })
}

/// Sharded estimate at *launch granularity*: the per-chip term uses the
/// fusion-plan pricing ([`crate::dfmodel::estimate_fused`] when `fused`,
/// [`crate::dfmodel::estimate_unfused`] otherwise) instead of the idealized
/// dataflow bound, so the fusion win composes with the `--chips` deployment
/// the CLI reports.
pub fn sharded_estimate_fused(
    model: ModelKind,
    dc: &DecoderConfig,
    chips: usize,
    cfg: &RduConfig,
    link: &InterchipLink,
    fused: bool,
) -> Result<ShardedEstimate, MapFailure> {
    sharded_estimate_fused_workload(family_workload(model), dc, chips, cfg, link, fused)
}

/// [`sharded_estimate_fused`] for any registered workload.
pub fn sharded_estimate_fused_workload(
    w: &dyn Workload,
    dc: &DecoderConfig,
    chips: usize,
    cfg: &RduConfig,
    link: &InterchipLink,
    fused: bool,
) -> Result<ShardedEstimate, MapFailure> {
    use crate::dfmodel::{estimate_fused, estimate_unfused};
    let (graph, comm_bytes, comm_seconds) = sharded_graph_and_comm(w, dc, chips, link);
    let per_chip =
        if fused { estimate_fused(&graph, cfg)? } else { estimate_unfused(&graph, cfg)? };
    Ok(ShardedEstimate {
        model: w.family(),
        workload: w.name(),
        chips,
        comm_seconds,
        comm_bytes,
        total_seconds: per_chip.total_seconds + comm_seconds,
        per_chip,
    })
}

/// One chip's workload graph plus the inter-chip communication term of the
/// sharded dataflow — the part shared by the idealized and fusion-aware
/// sharded estimates. The graph comes straight from the workload trait;
/// the [`ShardComm`] pattern is priced here over `link`:
///
/// * [`ShardComm::CarryExchange`] — one composed `(A, B)` pair per scan
///   channel through the `2·⌈log₂P⌉`-round inter-chip exclusive prefix
///   ([`InterchipLink::prefix_exchange_seconds`]).
/// * [`ShardComm::AllToAllTranspose`] — per transform, an all-to-all of
///   the distributed padded `fft_len × D` complex tensor, each chip holding
///   `1/P` of it ([`InterchipLink::all_to_all_seconds`]).
fn sharded_graph_and_comm(
    w: &dyn Workload,
    dc: &DecoderConfig,
    chips: usize,
    link: &InterchipLink,
) -> (crate::graph::Graph, f64, f64) {
    assert!(chips >= 1, "sharded_estimate: need at least one chip");
    assert!(
        dc.seq_len % chips == 0,
        "sharded_estimate: {chips} chips must divide L={}",
        dc.seq_len
    );
    let graph = w.shard_local_graph(dc, chips);
    let (comm_bytes, comm_seconds) = match w.shard_comm(dc) {
        ShardComm::CarryExchange { channels } => {
            let carry = super::scan::carry_exchange_bytes(channels, dc.dtype_bytes);
            let bytes = prefix_exchange_steps(chips) as f64 * carry;
            (bytes, link.prefix_exchange_seconds(chips, carry))
        }
        ShardComm::AllToAllTranspose { transforms } => {
            let elem_bytes = 2.0 * dc.dtype_bytes; // complex
            let tensor = dc.fft_len() as f64 * dc.d_model as f64 * elem_bytes;
            let bytes = transforms
                * super::fft::transpose_bytes(dc.fft_len(), chips, elem_bytes)
                * dc.d_model as f64;
            let secs = transforms * link.all_to_all_seconds(chips, tensor / chips as f64);
            (bytes, secs)
        }
        ShardComm::Unsupported => panic!(
            "sharded_estimate: sequence sharding covers the SSM decoders, not {}",
            w.name()
        ),
    };
    (graph, comm_bytes, comm_seconds)
}

/// Strong-scaling sweep for a serving family's canonical workload — the
/// ModelKind-keyed wrapper over [`strong_scaling_workload`].
pub fn strong_scaling(
    model: ModelKind,
    dc: &DecoderConfig,
    chip_counts: &[usize],
    cfg: &RduConfig,
    link: &InterchipLink,
) -> Result<Vec<ScalingPoint>, MapFailure> {
    strong_scaling_workload(family_workload(model), dc, chip_counts, cfg, link)
}

/// Strong-scaling sweep: the same total sequence `dc.seq_len` over each
/// chip count, with speedup measured against a single-chip estimate of the
/// same total `L` (reused from the sweep when it contains chip count 1,
/// computed once otherwise).
pub fn strong_scaling_workload(
    w: &dyn Workload,
    dc: &DecoderConfig,
    chip_counts: &[usize],
    cfg: &RduConfig,
    link: &InterchipLink,
) -> Result<Vec<ScalingPoint>, MapFailure> {
    let mut ests = Vec::with_capacity(chip_counts.len());
    for &p in chip_counts {
        ests.push(sharded_estimate_workload(w, dc, p, cfg, link)?);
    }
    let single = match ests.iter().find(|e| e.chips == 1) {
        Some(e) => e.total_seconds,
        None => sharded_estimate_workload(w, dc, 1, cfg, link)?.total_seconds,
    };
    Ok(ests
        .into_iter()
        .map(|est| {
            let speedup = single / est.total_seconds;
            ScalingPoint { est, speedup }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dc() -> DecoderConfig {
        DecoderConfig::paper(1 << 20)
    }

    #[test]
    fn single_chip_matches_dfmodel_exactly() {
        let link = InterchipLink::rdu_fabric();
        for (model, cfg) in [
            (ModelKind::Mamba, RduConfig::hs_scan_mode()),
            (ModelKind::Hyena, RduConfig::fft_mode()),
        ] {
            let s = sharded_estimate(model, &dc(), 1, &cfg, &link).unwrap();
            assert_eq!(s.comm_seconds, 0.0);
            assert_eq!(s.comm_bytes, 0.0);
            assert_eq!(s.total_seconds, s.per_chip.total_seconds);
        }
    }

    #[test]
    fn mamba_scales_strongly() {
        // The carry exchange moves O(1) bytes, so Mamba's speedup must
        // clearly beat one chip and grow (to a small tolerance — the last
        // doubling's compute saving can approach the added exchange rounds).
        let link = InterchipLink::rdu_fabric();
        let cfg = RduConfig::hs_scan_mode();
        let pts = strong_scaling(ModelKind::Mamba, &dc(), &[1, 2, 4, 8], &cfg, &link).unwrap();
        assert!((pts[0].speedup - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(
                w[1].speedup > w[0].speedup * 0.95,
                "{} chips {} → {} chips {}",
                w[0].est.chips,
                w[0].speedup,
                w[1].est.chips,
                w[1].speedup
            );
        }
        let last = pts.last().unwrap();
        assert!(last.speedup > 1.5, "8-chip speedup {}", last.speedup);
        assert!(last.est.comm_share() < 0.9);
    }

    #[test]
    fn hyena_sweep_reports_comm_share() {
        // Hyena's all-to-all moves the whole padded tensor, so its scaling
        // may be interconnect-bound — the sweep must still report finite
        // latency and a meaningful communication share at every chip count.
        let link = InterchipLink::rdu_fabric();
        let pts =
            strong_scaling(ModelKind::Hyena, &dc(), &[1, 2, 4, 8], &RduConfig::fft_mode(), &link)
                .unwrap();
        assert!((pts[0].speedup - 1.0).abs() < 1e-12);
        assert_eq!(pts[0].est.comm_share(), 0.0);
        for p in &pts[1..] {
            assert!(p.est.total_seconds.is_finite() && p.est.total_seconds > 0.0);
            assert!(p.est.comm_share() > 0.0 && p.est.comm_share() < 1.0);
            assert!(p.speedup > 0.0);
        }
        // Per-chip traffic shrinks with P, so the exchange itself gets
        // cheaper as the fleet grows (bandwidth-dominated regime at 1M).
        for w in pts.windows(2).skip(1) {
            assert!(
                w[1].est.comm_seconds < w[0].est.comm_seconds * 1.001,
                "{} chips {} vs {} chips {}",
                w[0].est.chips,
                w[0].est.comm_seconds,
                w[1].est.chips,
                w[1].est.comm_seconds
            );
        }
    }

    #[test]
    fn hyena_pays_more_interconnect_than_mamba() {
        // The all-to-all moves O(L) tensor; the carry exchange moves O(1)
        // carries — the sharded-dataflow asymmetry in one assert.
        let link = InterchipLink::rdu_fabric();
        let hy =
            sharded_estimate(ModelKind::Hyena, &dc(), 8, &RduConfig::fft_mode(), &link).unwrap();
        let ma = sharded_estimate(ModelKind::Mamba, &dc(), 8, &RduConfig::hs_scan_mode(), &link)
            .unwrap();
        assert!(hy.comm_bytes > ma.comm_bytes * 100.0, "hy={} ma={}", hy.comm_bytes, ma.comm_bytes);
        assert!(hy.comm_seconds > ma.comm_seconds);
    }

    #[test]
    fn slower_links_raise_comm_share() {
        let fast = InterchipLink::rdu_fabric();
        let slow = InterchipLink::pcie5();
        let cfg = RduConfig::fft_mode();
        let a = sharded_estimate(ModelKind::Hyena, &dc(), 4, &cfg, &fast).unwrap();
        let b = sharded_estimate(ModelKind::Hyena, &dc(), 4, &cfg, &slow).unwrap();
        assert!(b.comm_share() > a.comm_share());
        assert_eq!(a.comm_bytes, b.comm_bytes, "traffic is link-independent");
    }

    #[test]
    fn fused_sharded_beats_unfused_sharded() {
        // The fusion win composes with sharding: at any chip count the
        // communication term is identical, so the per-chip launch savings
        // carry straight through to the total.
        let link = InterchipLink::rdu_fabric();
        let dc = DecoderConfig::paper(1 << 12); // the ISSUE-3 L = 4K point
        for (model, cfg) in [
            (ModelKind::Mamba, RduConfig::hs_scan_mode()),
            (ModelKind::Hyena, RduConfig::fft_mode()),
        ] {
            for chips in [1usize, 2] {
                let f = sharded_estimate_fused(model, &dc, chips, &cfg, &link, true).unwrap();
                let u = sharded_estimate_fused(model, &dc, chips, &cfg, &link, false).unwrap();
                assert_eq!(f.comm_seconds, u.comm_seconds);
                assert_eq!(f.comm_bytes, u.comm_bytes);
                assert!(
                    f.total_seconds < u.total_seconds,
                    "{model} chips={chips}: fused {} !< unfused {}",
                    f.total_seconds,
                    u.total_seconds
                );
            }
        }
    }

    #[test]
    fn attribution_carries_the_interchip_term() {
        let link = InterchipLink::rdu_fabric();
        let s = sharded_estimate(ModelKind::Hyena, &dc(), 8, &RduConfig::fft_mode(), &link)
            .unwrap();
        let a = s.attribution();
        assert_eq!(a.interchip_seconds, s.comm_seconds);
        assert!(a.interchip_seconds > 0.0);
        let per_chip = s.per_chip.attribution();
        assert_eq!(a.compute_seconds, per_chip.compute_seconds);
        assert_eq!(a.dram_seconds, per_chip.dram_seconds);
        assert!(a.summary().contains("interchip"));
    }

    #[test]
    #[should_panic(expected = "not attention")]
    fn attention_is_rejected() {
        let _ = sharded_estimate(
            ModelKind::Attention,
            &dc(),
            2,
            &RduConfig::baseline(),
            &InterchipLink::rdu_fabric(),
        );
    }

    #[test]
    fn every_ssm_workload_shards_through_the_registry() {
        let link = InterchipLink::rdu_fabric();
        for w in crate::workloads::ssm_workloads() {
            let cfg = w.extended_config();
            let s = sharded_estimate_workload(w, &dc(), 4, &cfg, &link).unwrap();
            assert_eq!(s.workload, w.name());
            assert!(s.total_seconds.is_finite() && s.total_seconds > 0.0, "{}", w.name());
            assert!(s.comm_seconds > 0.0, "{}: 4 chips must exchange", w.name());
            assert_eq!(s.total_seconds, s.per_chip.total_seconds + s.comm_seconds);
        }
    }

    #[test]
    fn s4_exchanges_half_of_hyenas_transposes() {
        // Three transforms per layer vs six: identical per-transform
        // traffic, so S4's exchange bytes are exactly half.
        let link = InterchipLink::rdu_fabric();
        let hy = sharded_estimate_workload(
            crate::workloads::lookup("hyena").unwrap(),
            &dc(),
            8,
            &RduConfig::fft_mode(),
            &link,
        )
        .unwrap();
        let s4 = sharded_estimate_workload(
            crate::workloads::lookup("s4").unwrap(),
            &dc(),
            8,
            &RduConfig::fft_mode(),
            &link,
        )
        .unwrap();
        assert!((s4.comm_bytes - hy.comm_bytes / 2.0).abs() / hy.comm_bytes < 1e-12);
    }

    #[test]
    fn ssd_rides_the_mamba_carry_exchange() {
        // Same sharding pattern, same wire bytes as the selective scan.
        let link = InterchipLink::rdu_fabric();
        let ma = sharded_estimate(ModelKind::Mamba, &dc(), 8, &RduConfig::hs_scan_mode(), &link)
            .unwrap();
        let ssd = sharded_estimate_workload(
            crate::workloads::lookup("ssd").unwrap(),
            &dc(),
            8,
            &RduConfig::baseline(),
            &link,
        )
        .unwrap();
        assert_eq!(ssd.comm_bytes, ma.comm_bytes);
        assert_eq!(ssd.comm_seconds, ma.comm_seconds);
        assert_eq!(ssd.model, ModelKind::Mamba, "SSD serves through the Mamba family");
    }
}
