//! Sharded Bailey FFT: the 4-step `R × C` decomposition distributed over
//! chips with one **all-to-all transpose** between the column and row
//! phases.
//!
//! Bailey's algorithm ([`crate::fft::bailey`]) already factors an L-point
//! FFT into independent length-R column transforms, a twiddle scaling, and
//! independent length-C row transforms — exactly the two-phase structure a
//! multi-chip mapping wants. With `P` chips:
//!
//! ```text
//! phase 1 (parallel)   chip p: FFT + twiddle its C/P owned columns
//! phase 2 (exchange)   all-to-all transpose: chip p gathers rows
//!                      [p·R/P, (p+1)·R/P) — every chip sends (P−1)/P of
//!                      its matrix slice to peers
//! phase 3 (parallel)   chip p: FFT its R/P rows (length C, recursing
//!                      through the single-chip Bailey tiling)
//! ```
//!
//! The arithmetic is identical to the single-chip decomposition — only
//! *ownership* moves — so the result is exact against [`crate::fft::dft()`]
//! to floating-point rounding. Wire cost is priced by
//! [`crate::arch::InterchipLink::all_to_all_seconds`].

use crate::fft::{bailey_fft, is_pow2, BaileyVariant};
use crate::runtime::WorkerPool;
use crate::util::C64;
use std::f64::consts::PI;
use std::ops::Range;

/// Phase 1 for one chip: FFT + twiddle the columns it owns. Shared by the
/// serial and pooled drivers so they are bit-identical by construction.
fn chip_columns(
    x: &[C64],
    r: usize,
    c: usize,
    cols: Range<usize>,
    variant: BaileyVariant,
) -> Vec<Vec<C64>> {
    let l = x.len();
    cols.map(|n2| {
        let col: Vec<C64> = (0..r).map(|n1| x[n1 * c + n2]).collect();
        let mut col = bailey_fft(&col, r, variant);
        for (k1, v) in col.iter_mut().enumerate() {
            let ang = -2.0 * PI * ((n2 * k1) % l) as f64 / l as f64;
            *v = *v * C64::cis(ang);
        }
        col
    })
    .collect()
}

/// Phase 3 for one chip: FFT the rows it owns (post-transpose), returning
/// `(k1, row_spectrum)` pairs for the caller to scatter into 4-step order.
fn chip_rows(
    cols: &[Vec<C64>],
    r: usize,
    c: usize,
    rows: Range<usize>,
    variant: BaileyVariant,
) -> Vec<(usize, Vec<C64>)> {
    rows.map(|k1| {
        let row: Vec<C64> = (0..c).map(|n2| cols[n2][k1]).collect();
        (k1, bailey_fft(&row, r, variant))
    })
    .collect()
}

/// Bailey 4-step FFT of `x` with tile size `r`, sharded over `chips` chips.
///
/// Requirements: `x.len()` and `r` powers of two with `r ≥ 2` (as
/// [`crate::fft::bailey_fft`]); when `chips > 1` and the input spans more
/// than one tile, `chips` must divide both the row count `r` and the column
/// count `x.len() / r` so each phase partitions evenly. Inputs of at most
/// one tile, or `chips == 1`, fall back to the single-chip transform.
pub fn sharded_bailey_fft(x: &[C64], r: usize, chips: usize, variant: BaileyVariant) -> Vec<C64> {
    sharded_bailey_fft_pooled(x, r, chips, variant, &WorkerPool::serial())
}

/// [`sharded_bailey_fft`] with the two per-chip parallel phases (column
/// FFTs + twiddles, row FFTs) fanned across `pool`'s worker threads —
/// the host-compute mirror of the multi-chip execution. Per-chip
/// arithmetic is shared with the serial driver, so the output is
/// **bit-identical** to it (asserted by the integration tests).
pub fn sharded_bailey_fft_pooled(
    x: &[C64],
    r: usize,
    chips: usize,
    variant: BaileyVariant,
    pool: &WorkerPool,
) -> Vec<C64> {
    let l = x.len();
    assert!(chips >= 1, "sharded_bailey_fft: need at least one chip");
    if chips == 1 || l <= r {
        // One chip, or a single tile: nothing to shard.
        return bailey_fft(x, r, variant);
    }
    assert!(is_pow2(l), "sharded_bailey_fft: L={l} not a power of two");
    assert!(is_pow2(r) && r >= 2, "sharded_bailey_fft: R={r} not a power of two >= 2");
    let c = l / r;
    assert!(
        r % chips == 0 && c % chips == 0,
        "sharded_bailey_fft: {chips} chips must divide both R={r} rows and C={c} columns"
    );
    run_sharded(x, r, chips, variant, pool)
}

/// The three-phase sharded dataflow; `pool` fans the per-chip phases.
fn run_sharded(
    x: &[C64],
    r: usize,
    chips: usize,
    variant: BaileyVariant,
    pool: &WorkerPool,
) -> Vec<C64> {
    let l = x.len();
    let c = l / r;

    // Phase 1 — chip p owns columns [p·C/P, (p+1)·C/P): length-R column
    // FFTs (x[n1·C + n2], the 4-step decimation) plus the twiddle scaling
    // T[n2, k1] *= e^{-2πi·n2·k1/L}, all chip-local.
    let cols_per_chip = c / chips;
    let cols: Vec<Vec<C64>> = {
        let _t = crate::telemetry::span("shard", "fft.columns").arg("chips", chips as f64);
        pool.map(chips, |p| {
            chip_columns(x, r, c, p * cols_per_chip..(p + 1) * cols_per_chip, variant)
        })
        .concat()
    };

    // Phase 2 — the all-to-all transpose: chip p needs row k1 ∈
    // [p·R/P, (p+1)·R/P) of a matrix whose columns live across all chips.
    // (In this functional model the gather is just indexing; the
    // interconnect model prices the (P−1)/P of the matrix that crosses
    // chip boundaries.)
    {
        let wire = transpose_bytes(l, chips, 16.0);
        let _t = crate::telemetry::span("shard", "fft.transpose").arg("bytes", wire);
        if crate::telemetry::enabled() {
            for p in 0..chips {
                let track = crate::telemetry::chip_track(p);
                crate::telemetry::name_track(
                    crate::telemetry::PID_HOST,
                    track,
                    format!("chip {p}"),
                );
                crate::telemetry::instant_on(
                    "shard",
                    "fft.transpose",
                    track,
                    "bytes",
                    wire / chips as f64,
                );
            }
        }
    }

    // Phase 3 — chip p: length-C row FFTs through the single-chip Bailey
    // tiling, scattered to the standard 4-step output order X[k1 + R·k2].
    let _t = crate::telemetry::span("shard", "fft.rows").arg("chips", chips as f64);
    let rows_per_chip = r / chips;
    let rows: Vec<Vec<(usize, Vec<C64>)>> = pool.map(chips, |p| {
        chip_rows(&cols, r, c, p * rows_per_chip..(p + 1) * rows_per_chip, variant)
    });
    let mut out = vec![C64::ZERO; l];
    for (k1, row_f) in rows.into_iter().flatten() {
        for (k2, v) in row_f.into_iter().enumerate() {
            out[k1 + r * k2] = v;
        }
    }
    out
}

/// Total bytes that cross chip boundaries in the transpose of an L-point
/// matrix distributed over `chips` chips: each chip keeps its `1/P`
/// diagonal block and sends the rest, so `(P−1)/P` of the whole tensor
/// moves (`bytes_per_elem` = complex element size).
pub fn transpose_bytes(l: usize, chips: usize, bytes_per_elem: f64) -> f64 {
    if chips <= 1 {
        return 0.0;
    }
    l as f64 * bytes_per_elem * (chips as f64 - 1.0) / chips as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft::dft, fft};
    use crate::util::complex::max_abs_diff_c;
    use crate::util::XorShift;

    fn rand_complex(rng: &mut XorShift, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0))).collect()
    }

    #[test]
    fn matches_dft_across_chip_counts() {
        let mut rng = XorShift::new(71);
        for &(l, r) in &[(256usize, 32usize), (512, 16), (1024, 32)] {
            let x = rand_complex(&mut rng, l);
            let want = dft(&x);
            for chips in [1usize, 2, 4, 8] {
                for variant in [BaileyVariant::Vector, BaileyVariant::Gemm] {
                    let got = sharded_bailey_fft(&x, r, chips, variant);
                    let d = max_abs_diff_c(&got, &want);
                    assert!(d < 1e-7, "L={l} R={r} chips={chips} {variant:?}: diff={d}");
                }
            }
        }
    }

    #[test]
    fn matches_single_chip_bailey_exactly_in_structure() {
        // Same arithmetic, different ownership: sharded output must agree
        // with the single-chip CT pipeline to tight tolerance.
        let mut rng = XorShift::new(72);
        let x = rand_complex(&mut rng, 2048);
        let got = sharded_bailey_fft(&x, 32, 4, BaileyVariant::Vector);
        assert!(max_abs_diff_c(&got, &fft(&x)) < 1e-8);
    }

    #[test]
    fn single_tile_and_single_chip_fall_back() {
        let mut rng = XorShift::new(73);
        let x = rand_complex(&mut rng, 16);
        // L ≤ R: the input is one tile; any chip count degenerates cleanly.
        let got = sharded_bailey_fft(&x, 32, 8, BaileyVariant::Vector);
        assert!(max_abs_diff_c(&got, &fft(&x)) < 1e-9);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn indivisible_partition_rejected() {
        let x = vec![C64::ZERO; 128];
        // C = 128/32 = 4 columns cannot split over 8 chips.
        sharded_bailey_fft(&x, 32, 8, BaileyVariant::Vector);
    }

    #[test]
    fn pooled_fft_bit_identical_to_serial() {
        let mut rng = XorShift::new(74);
        let pool = WorkerPool::new(3);
        for &(l, r) in &[(256usize, 32usize), (2048, 32)] {
            let x = rand_complex(&mut rng, l);
            for chips in [1usize, 2, 4] {
                for variant in [BaileyVariant::Vector, BaileyVariant::Gemm] {
                    assert_eq!(
                        sharded_bailey_fft_pooled(&x, r, chips, variant, &pool),
                        sharded_bailey_fft(&x, r, chips, variant),
                        "L={l} R={r} chips={chips} {variant:?}: pooling must be bit-exact"
                    );
                }
            }
        }
    }

    #[test]
    fn transpose_traffic_fraction() {
        // 4 chips: 3/4 of the tensor crosses the fabric.
        assert_eq!(transpose_bytes(1024, 4, 16.0), 1024.0 * 16.0 * 0.75);
        assert_eq!(transpose_bytes(1024, 1, 16.0), 0.0);
    }
}
