//! Observability overhead gate (`BENCH_observe.json`): tracing **off** must
//! cost ≤1% on the instrumented hot paths — the paper's "<1% overhead"
//! discipline, enforced in CI next to the fusion and hotpath gates.
//!
//! Method: a disabled span/instant site is one relaxed atomic load and a
//! branch. We measure that per-site cost directly, count how many sites one
//! hot-path call actually crosses (by enabling tracing once and counting
//! the recorded events), and bound the relative overhead as
//!
//! ```text
//! overhead_share = sites_per_call × disabled_site_cost / call_latency
//! ```
//!
//! which over-counts (instants and counter bumps are cheaper than the span
//! bound) — a conservative gate. The tracing-*on* cost is also reported,
//! informationally: it is allowed to cost more; only the default-off mode
//! is gated.

use ssm_rdu::bench::{black_box, Bencher};
use ssm_rdu::fft::{fft_conv_linear, BaileyVariant};
use ssm_rdu::runtime::WorkerPool;
use ssm_rdu::shard::{sharded_bailey_fft_pooled, sharded_mamba_scan_pooled};
use ssm_rdu::telemetry;
use ssm_rdu::util::{C64, XorShift};

/// CI gate: disabled-mode telemetry overhead on any hot-path group.
const GATE_MAX_OVERHEAD: f64 = 0.01;

fn main() {
    let mut b = Bencher::from_env("observe");

    // -- 1. The per-site disabled cost: open-and-drop SPAN_BATCH inert
    //       spans (plus an instant each) per iteration.
    const SPAN_BATCH: usize = 1000;
    assert!(!telemetry::enabled(), "bench must start with tracing off");
    let span_batch_s = b
        .bench("disabled_span_x1000", || {
            for _ in 0..SPAN_BATCH {
                let _t = telemetry::span("bench", "noop").arg("x", 1.0);
                telemetry::instant_arg("bench", "noop", "x", 1.0);
                black_box(());
            }
        })
        .min;
    // Per site: each loop pass crosses one span site and one instant site.
    let site_ns_off = span_batch_s * 1e9 / (SPAN_BATCH * 2) as f64;
    b.metric("disabled_site_ns", site_ns_off);

    // -- 2. Hot-path latencies with tracing off (the shipped default).
    let pool = WorkerPool::new(4);
    let mut rng = XorShift::new(5);
    let n = 1 << 14;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let bb: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let scan_off = b
        .bench("sharded_scan_8chip_off", || {
            black_box(sharded_mamba_scan_pooled(&a, &bb, 8, &pool));
        })
        .min;

    let x: Vec<C64> = (0..4096)
        .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
        .collect();
    let fft_off = b
        .bench("sharded_fft_4chip_off", || {
            black_box(sharded_bailey_fft_pooled(&x, 32, 4, BaileyVariant::Vector, &pool));
        })
        .min;

    let u = vec![1.0f64; 4096];
    let k = vec![0.5f64; 4096];
    let conv_off = b
        .bench("fft_conv_linear_off", || {
            black_box(fft_conv_linear(&u, &k));
        })
        .min;

    // -- 3. Count the telemetry sites each call crosses: run once with
    //       tracing on and count what lands in the sink. Counter bumps
    //       (always on) are charged at the same per-site bound.
    let events_of = |f: &dyn Fn()| -> usize {
        telemetry::drain();
        telemetry::enable();
        f();
        telemetry::disable();
        telemetry::drain().len()
    };
    let scan_sites = events_of(&|| {
        black_box(sharded_mamba_scan_pooled(&a, &bb, 8, &pool));
    });
    let fft_sites = events_of(&|| {
        black_box(sharded_bailey_fft_pooled(&x, 32, 4, BaileyVariant::Vector, &pool));
    });
    // Conv records no events but bumps the plan-cache hit/miss counter once
    // per call; charge it one site.
    let conv_sites = 1usize;

    // -- 4. The gate: bounded share of each hot-path latency.
    let share = |sites: usize, off_s: f64| sites as f64 * site_ns_off / (off_s * 1e9);
    let shares = [
        ("scan", scan_sites, share(scan_sites, scan_off)),
        ("fft", fft_sites, share(fft_sites, fft_off)),
        ("conv", conv_sites, share(conv_sites, conv_off)),
    ];
    for (name, sites, sh) in &shares {
        b.metric(&format!("{name}_sites_per_call"), *sites as f64);
        b.metric(&format!("{name}_overhead_share_off"), *sh);
    }
    b.metric("gate_max_overhead", GATE_MAX_OVERHEAD);

    // -- 5. Informational: the same scan with tracing ON (not gated).
    telemetry::enable();
    let scan_on = b
        .bench("sharded_scan_8chip_on", || {
            black_box(sharded_mamba_scan_pooled(&a, &bb, 8, &pool));
        })
        .min;
    let on_ratio = scan_on / scan_off;
    telemetry::disable();
    telemetry::drain();
    b.metric("scan_on_over_off_ratio", on_ratio);

    // Write BENCH_observe.json before any gate verdict so a failure still
    // leaves the numbers on disk for the perf-trajectory artifact.
    b.finish();

    let worst = shares.iter().copied().fold(("", 0usize, 0.0f64), |acc, s| {
        if s.2 > acc.2 {
            s
        } else {
            acc
        }
    });
    if worst.2 > GATE_MAX_OVERHEAD {
        eprintln!(
            "OBSERVABILITY OVERHEAD REGRESSION: disabled-mode telemetry costs {:.3}% of the \
             `{}` hot path ({} sites × {:.1} ns/site) — gate is {:.0}%",
            worst.2 * 100.0,
            worst.0,
            worst.1,
            site_ns_off,
            GATE_MAX_OVERHEAD * 100.0
        );
        std::process::exit(1);
    }
    println!(
        "observe gate OK: worst disabled-mode share {:.4}% on `{}` ({} sites, {:.1} ns/site, \
         gate {:.0}%); tracing-on scan ratio {:.2}x (informational)",
        worst.2 * 100.0,
        worst.0,
        worst.1,
        site_ns_off,
        GATE_MAX_OVERHEAD * 100.0,
        on_ratio
    );
}
