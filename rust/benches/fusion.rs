//! Fusion bench + perf-regression gate: fused vs kernel-by-kernel DFModel
//! latency for every registered SSM decoder (hyena, mamba, ssd, s4 — the
//! table follows the workload registry), serialized to
//! `BENCH_fusion.json` (run with `--json`; CI archives it as an artifact).
//!
//! This target doubles as the gate: it **exits non-zero if the fused
//! mapping is not strictly faster than the unfused one** at any swept
//! point, so a regression in the fusion pass fails CI rather than silently
//! eroding the headline win.
//!
//!     cargo bench --bench fusion -- --quick --json

use ssm_rdu::bench::Bencher;
use ssm_rdu::dfmodel;
use ssm_rdu::figures;

fn main() {
    let mut b = Bencher::from_env("fusion");

    // The model-level trajectory: fused vs unfused latency at the ISSUE-3
    // acceptance point (L = 4K) and two production lengths.
    let lens = [1usize << 12, 1 << 16, 1 << 20];
    let points = b.report("fusion_at {4K,64K,1M}", || figures::fusion_at(&lens));
    figures::fusion_table(&points).print();
    let mut regressions = Vec::new();
    for p in &points {
        let l = p.seq_len;
        b.metric(&format!("{}_unfused_s_L{l}", p.model), p.unfused_seconds);
        b.metric(&format!("{}_fused_s_L{l}", p.model), p.fused_seconds);
        b.metric(&format!("{}_fusion_gain_L{l}", p.model), p.gain());
        b.metric(&format!("{}_launches_L{l}", p.model), p.launches as f64);
        b.metric(&format!("{}_staged_fused_bytes_L{l}", p.model), p.staged_fused);
        let strictly_faster = p.fused_seconds.is_finite() && p.fused_seconds < p.unfused_seconds;
        if !strictly_faster {
            regressions.push(format!(
                "{} @ L={l}: fused {} !< unfused {}",
                p.model, p.fused_seconds, p.unfused_seconds
            ));
        }
    }

    // Wall-time of the pass itself: fusing + pricing must stay cheap enough
    // to run per mapping query.
    {
        use ssm_rdu::arch::RduConfig;
        use ssm_rdu::fft::BaileyVariant;
        use ssm_rdu::workloads::{hyena_decoder, DecoderConfig};
        let g = hyena_decoder(&DecoderConfig::paper(1 << 20), BaileyVariant::Vector);
        let cfg = RduConfig::fft_mode();
        b.bench("fuse_graph hyena (L=1M)", || dfmodel::fuse_graph(&g, &cfg));
        b.bench("estimate_fused hyena (L=1M)", || dfmodel::estimate_fused(&g, &cfg).unwrap());
    }

    b.finish();

    if !regressions.is_empty() {
        eprintln!("FUSION PERF REGRESSION:");
        for r in &regressions {
            eprintln!("  {r}");
        }
        std::process::exit(1);
    }
}
