//! Multi-chip strong-scaling benchmarks: the sharded Mamba scan and sharded
//! Bailey FFT numerics across chip counts, plus the DFModel strong-scaling
//! report (speedup over one chip and communication share per chip count)
//! for both SSM decoders — the numbers behind `simulate --chips`.

use ssm_rdu::arch::{InterchipLink, RduConfig};
use ssm_rdu::bench::Bencher;
use ssm_rdu::fft::BaileyVariant;
use ssm_rdu::runtime::ModelKind;
use ssm_rdu::shard::{sharded_bailey_fft, sharded_mamba_scan, strong_scaling};
use ssm_rdu::util::{fmt_time, C64, XorShift};
use ssm_rdu::workloads::DecoderConfig;

fn main() {
    let mut b = Bencher::from_env("shard_scaling");
    let link = InterchipLink::rdu_fabric();
    let chip_counts = [1usize, 2, 4, 8];

    // Numeric substrate across chip counts (fixed total work: the
    // functional model is single-threaded, so this tracks the sharding
    // overhead — carry bookkeeping and the transpose-shaped indexing —
    // not wall-clock parallelism).
    let mut rng = XorShift::new(41);
    let n = 1 << 16;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let bb: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    for &chips in &chip_counts {
        b.bench(&format!("sharded mamba scan N=64K, {chips} chip(s)"), || {
            sharded_mamba_scan(&a, &bb, chips)
        });
    }
    let x: Vec<C64> = (0..(1 << 14))
        .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
        .collect();
    for &chips in &chip_counts {
        b.bench(&format!("sharded bailey fft L=16K R=32, {chips} chip(s)"), || {
            sharded_bailey_fft(&x, 32, chips, BaileyVariant::Vector)
        });
    }

    // The strong-scaling report at the paper shape (L = 1M).
    let dc = DecoderConfig::paper(1 << 20);
    for (model, cfg) in [
        (ModelKind::Mamba, RduConfig::hs_scan_mode()),
        (ModelKind::Hyena, RduConfig::fft_mode()),
    ] {
        let pts = b.report(&format!("strong scaling: {model} @ L=1M over {link}"), || {
            strong_scaling(model, &dc, &chip_counts, &cfg, &link).expect("mappable")
        });
        for pt in &pts {
            println!(
                "  {model} × {} chip(s): per-chip {} + comm {} = {}  speedup {:.2}x  \
                 comm share {:.1}%",
                pt.est.chips,
                fmt_time(pt.est.per_chip.total_seconds),
                fmt_time(pt.est.comm_seconds),
                fmt_time(pt.est.total_seconds),
                pt.speedup,
                pt.est.comm_share() * 100.0,
            );
        }
    }

    b.finish();
}
