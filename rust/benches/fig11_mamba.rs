//! Bench E4 — Figure 11: the five Mamba-side designs (attention, C-scan,
//! parallel-scan/baseline, parallel-scan/HS-mode, parallel-scan/B-mode)
//! across L ∈ {256K, 512K, 1M}, with paper-vs-measured speedups.

use ssm_rdu::arch::RduConfig;
use ssm_rdu::bench::Bencher;
use ssm_rdu::dfmodel;
use ssm_rdu::figures::mamba::fig11;
use ssm_rdu::workloads::{mamba_decoder, DecoderConfig, ScanVariant};

fn main() {
    let mut b = Bencher::from_env("fig11_mamba");
    let f = b.report("Fig. 11 dataset (DFModel, paper sweep)", fig11);
    f.table().print();
    f.speedup_report().print();

    let dc = DecoderConfig::paper(1 << 20);
    let cfg = RduConfig::hs_scan_mode();
    b.bench("build mamba graph (L=1M)", || mamba_decoder(&dc, ScanVariant::Parallel));
    let g = mamba_decoder(&dc, ScanVariant::Parallel);
    b.bench("dfmodel::estimate mamba (L=1M)", || dfmodel::estimate(&g, &cfg).unwrap());
    b.finish();
}
