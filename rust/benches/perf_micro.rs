//! Hot-path microbenchmarks + the compute-engine perf gate.
//!
//! Measures the three engine wins of the hot-path pass on L ∈ {1k, 4k, 16k}
//! and the pre-existing layer hot paths, then writes the machine-readable
//! trajectory to `BENCH_hotpath.json` at the repo root (run with `--json`):
//!
//! * **planned vs naive** — [`FftPlan`]'s cached twiddle/bit-reversal
//!   tables vs the per-call-trig Cooley–Tukey transform;
//! * **real vs complex** — the rfft packing-trick convolution vs the
//!   planned full-complex pipeline (isolating the rfft win from the
//!   planning win);
//! * **pooled vs serial** — per-channel Hyena convolutions, per-chip
//!   sharded Mamba scan / Bailey FFT, and the pooled continuous-batching
//!   session sim over the `std::thread::scope` worker pool;
//! * **raw-speed pass (PR 7)** — split-radix vs radix-2 real-FFT engines
//!   at the 32k transform the L=16k conv runs, cache-blocked vs
//!   breadth-first traversal, chunked vs scalar scan/gate kernels, and
//!   `map_stealing` vs statically-chunked `map` on ragged job sets;
//! * **resident-team pass (PR 9)** — the resident `WorkerPool::map`
//!   facade vs the scoped spawn-per-call baseline (`map_spawn`) on the
//!   short-batch L=1k D=32 serve loop, and the explicit-lane SIMD scan
//!   kernel vs its scalar oracle (backend recorded in provenance).
//!
//! This target doubles as the CI gate: it **exits non-zero** unless
//!
//! * the planned real-input convolution is ≥1.5× the pre-plan naive
//!   complex path at **both** L = 4k and L = 16k (the split-radix regime),
//! * the per-channel Hyena convolution fan-out over a 4-thread pool is
//!   ≥3.0× its serial loop at L = 4k (ratcheted from 2.5× by the
//!   resident team's µs-scale park/wake),
//! * the resident team beats spawn-per-batch by ≥1.15× on the short-batch
//!   serve loop, and
//! * the SIMD Mamba scan is ≥2.5× its scalar oracle (ratcheted from the
//!   chunked kernel's 2.21×) — skipped on the portable fallback backend,
//!   where the two are the same code.
//!
//!     cargo bench --bench perf_micro -- --quick --json

use ssm_rdu::arch::{PcuGeometry, RduConfig};
use ssm_rdu::bench::{black_box, Bencher};
use ssm_rdu::coordinator::{
    run_batch, Batch, Executor, ExecutorFactory, Metrics, MockExecutor, Request,
};
use ssm_rdu::dfmodel;
use ssm_rdu::fft::{
    bailey_fft, fft, fft_conv_circular_naive, fft_conv_linear, fft_conv_linear_channels,
    to_complex, BaileyVariant, ConvPlan, CplxConvPlan, FftEngine, FftPlan, RealFftPlan,
};
use ssm_rdu::pcusim::{self, Pcu};
use ssm_rdu::runtime::{ModelKind, WorkerPool};
use ssm_rdu::scan::{
    blelloch_exclusive, c_scan_exclusive, gate_silu_chunked, gate_silu_scalar,
    hillis_steele_inclusive, mamba_scan_channels_chunked, mamba_scan_channels_scalar,
    mamba_scan_channels_simd, simd_backend, tiled_exclusive,
};
use ssm_rdu::session::driver::{simulate, simulate_pooled, SimConfig};
use ssm_rdu::shard::{
    sharded_bailey_fft, sharded_bailey_fft_pooled, sharded_mamba_scan, sharded_mamba_scan_pooled,
};
use ssm_rdu::util::{C64, XorShift};
use ssm_rdu::workloads::{hyena_decoder, DecoderConfig};
use std::sync::mpsc::channel;

/// The acceptance floors. Planned real-FFT conv vs naive complex is gated
/// at both L = 4k (the original engine-pass floor) and L = 16k (where the
/// conv's 32k-point transform runs on the split-radix engine); the pooled
/// per-channel fan-out is gated on a fixed 4-thread pool so the bar does
/// not drift with the runner's core count.
const GATE_L: usize = 1 << 12;
const GATE_L_16K: usize = 1 << 14;
const GATE_MIN_SPEEDUP: f64 = 1.5;
const GATE_POOL_THREADS: usize = 4;
/// PR 9 ratchet (was 2.5): the resident team's µs-scale park/wake removes
/// the per-call spawn tax the old floor priced in.
const GATE_POOL_MIN_SPEEDUP: f64 = 3.0;
/// Resident `map` vs spawn-per-batch `map_spawn` on the short-batch serve
/// loop (L=1k, D=32): residency must be worth ≥15%.
const GATE_TEAM_MIN_SPEEDUP: f64 = 1.15;
/// Explicit-lane SIMD scan vs its scalar oracle (ratcheted from the
/// chunked kernel's 2.21×). Only enforced when a real vector backend is
/// detected — the portable fallback *is* the chunked kernel.
const GATE_SIMD_SCAN_MIN_SPEEDUP: f64 = 2.5;

fn main() {
    let mut b = Bencher::from_env("hotpath");
    let mut rng = XorShift::new(99);
    // Uncached: the bench honours SSM_RDU_THREADS even if some earlier
    // code already resolved the process-wide cached pool.
    let pool = WorkerPool::from_env_uncached();
    b.metric("pool_threads", pool.threads() as f64);
    println!("simd backend: {}", simd_backend());
    // Backend provenance as a scalar: 0 = portable, 1 = avx, 2 = neon.
    b.metric(
        "simd_backend_code",
        match simd_backend() {
            "avx" => 1.0,
            "neon" => 2.0,
            _ => 0.0,
        },
    );

    // --- FFT substrate: planned vs naive transform ------------------------
    let x16k = to_complex(&rng.vec(1 << 14, -1.0, 1.0));
    b.bench("fft substrate: naive cooley-tukey 16K", || fft(&x16k));
    let plan16k = FftPlan::new(1 << 14);
    let mut fbuf = x16k.clone();
    b.bench("fft substrate: planned in-place 16K", || {
        fbuf.copy_from_slice(&x16k);
        plan16k.fft_in_place(&mut fbuf);
        fbuf[0]
    });
    b.bench("fft substrate: bailey-vector 16K (R=32)", || {
        bailey_fft(&x16k, 32, BaileyVariant::Vector)
    });
    b.bench("fft substrate: bailey-gemm 16K (R=32)", || {
        bailey_fft(&x16k, 32, BaileyVariant::Gemm)
    });

    // --- FFT substrate: split-radix engine + blocked traversal (PR 7) ----
    {
        let n = 1 << 15; // the transform length behind the L=16k linear conv
        let xr = rng.vec(n, -1.0, 1.0);
        let mut spec = vec![C64::ZERO; n / 2 + 1];
        let mut r2 = RealFftPlan::with_engine(n, FftEngine::Radix2);
        let mut sr = RealFftPlan::with_engine(n, FftEngine::SplitRadix);
        let t_r2 = b
            .bench("rfft engine: radix-2 32K", || {
                r2.rfft_into(&xr, &mut spec);
                spec[0]
            })
            .min;
        let t_sr = b
            .bench("rfft engine: split-radix 32K", || {
                sr.rfft_into(&xr, &mut spec);
                spec[0]
            })
            .min;
        b.metric("rfft_radix2_s_32k", t_r2);
        b.metric("rfft_splitradix_s_32k", t_sr);
        b.metric("rfft_splitradix_speedup_32k", t_r2 / t_sr);

        let mut cbuf = x16k.clone();
        let t_flat = b
            .bench("fft traversal: breadth-first 16K", || {
                cbuf.copy_from_slice(&x16k);
                plan16k.fft_in_place_flat(&mut cbuf);
                cbuf[0]
            })
            .min;
        let t_blocked = b
            .bench("fft traversal: cache-blocked 16K", || {
                cbuf.copy_from_slice(&x16k);
                plan16k.fft_in_place(&mut cbuf);
                cbuf[0]
            })
            .min;
        b.metric("fft_flat_s_16k", t_flat);
        b.metric("fft_blocked_s_16k", t_blocked);
        b.metric("fft_blocked_vs_flat_speedup_16k", t_flat / t_blocked);
    }

    // --- Chunked/SIMD scan/gate kernels vs their scalar oracles -----------
    let simd_scan_speedup;
    {
        let t = 1 << 12;
        let c = 64;
        let a: Vec<f64> = (0..t * c).map(|_| rng.uniform(0.1, 0.99)).collect();
        let bb = rng.vec(t * c, -1.0, 1.0);
        let t_scalar = b
            .bench("mamba scan channels: scalar T=4K C=64", || {
                mamba_scan_channels_scalar(&a, &bb, c)
            })
            .min;
        let t_chunked = b
            .bench("mamba scan channels: chunked T=4K C=64", || {
                mamba_scan_channels_chunked(&a, &bb, c)
            })
            .min;
        let t_simd = b
            .bench("mamba scan channels: simd T=4K C=64", || {
                mamba_scan_channels_simd(&a, &bb, c)
            })
            .min;
        b.metric("mamba_scan_channels_scalar_s", t_scalar);
        b.metric("mamba_scan_channels_chunked_s", t_chunked);
        b.metric("mamba_scan_channels_simd_s", t_simd);
        b.metric("mamba_scan_chunked_speedup", t_scalar / t_chunked);
        b.metric("mamba_scan_simd_speedup", t_scalar / t_simd);
        simd_scan_speedup = t_scalar / t_simd;

        let z = rng.vec(1 << 18, -4.0, 4.0);
        let g_scalar = b.bench("gate: silu scalar 256K", || gate_silu_scalar(&z, &z)).min;
        let g_chunked = b.bench("gate: silu chunked 256K", || gate_silu_chunked(&z, &z)).min;
        b.metric("gate_silu_chunked_speedup", g_scalar / g_chunked);
    }

    // --- Convolution engine: naive vs planned-complex vs planned-real ----
    let mut gate_speedup = 0.0f64;
    let mut gate_speedup_16k = 0.0f64;
    for l in [1usize << 10, 1 << 12, 1 << 14] {
        let u = rng.vec(l, -1.0, 1.0);
        let k = rng.vec(l, -1.0, 1.0);
        let naive =
            b.bench(&format!("conv: naive complex L={l}"), || fft_conv_circular_naive(&u, &k)).min;
        let mut cplx = CplxConvPlan::new(l);
        let planned_cplx =
            b.bench(&format!("conv: planned complex L={l}"), || cplx.circular(&u, &k)).min;
        let mut real = ConvPlan::new(l);
        let mut out = vec![0.0; l];
        let planned_real = b
            .bench(&format!("conv: planned real L={l}"), || {
                real.circular_into(&u, &k, &mut out);
                out[0]
            })
            .min;
        b.metric(&format!("conv_naive_complex_s_L{l}"), naive);
        b.metric(&format!("conv_planned_complex_s_L{l}"), planned_cplx);
        b.metric(&format!("conv_planned_real_s_L{l}"), planned_real);
        b.metric(&format!("conv_speedup_planned_vs_naive_L{l}"), naive / planned_cplx);
        b.metric(&format!("conv_speedup_real_vs_complex_L{l}"), planned_cplx / planned_real);
        b.metric(&format!("conv_speedup_planned_real_vs_naive_L{l}"), naive / planned_real);
        if l == GATE_L {
            gate_speedup = naive / planned_real;
        }
        if l == GATE_L_16K {
            gate_speedup_16k = naive / planned_real;
        }
    }

    // --- Pooled vs serial: per-channel Hyena convolutions -----------------
    for l in [1usize << 10, 1 << 12] {
        let d = 32;
        let us: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let ks: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let serial = b
            .bench(&format!("hyena channels: serial D=32 L={l}"), || {
                us.iter().zip(&ks).map(|(u, k)| fft_conv_linear(u, k)).collect::<Vec<_>>()
            })
            .min;
        let pooled = b
            .bench(&format!("hyena channels: pooled D=32 L={l}"), || {
                fft_conv_linear_channels(&us, &ks, &pool)
            })
            .min;
        b.metric(&format!("hyena_channels_serial_s_L{l}"), serial);
        b.metric(&format!("hyena_channels_pooled_s_L{l}"), pooled);
        b.metric(&format!("hyena_channels_pool_speedup_L{l}"), serial / pooled);
    }

    // --- Pooled gate: fixed 4-thread fan-out (PR 7) -----------------------
    let pool_gate_speedup;
    {
        let l = GATE_L;
        let d = 32;
        let pool4 = WorkerPool::new(GATE_POOL_THREADS);
        let us: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let ks: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let serial = b
            .bench("hyena channels gate: serial D=32 L=4K", || {
                us.iter().zip(&ks).map(|(u, k)| fft_conv_linear(u, k)).collect::<Vec<_>>()
            })
            .min;
        let pooled = b
            .bench("hyena channels gate: 4-thread D=32 L=4K", || {
                fft_conv_linear_channels(&us, &ks, &pool4)
            })
            .min;
        pool_gate_speedup = serial / pooled;
        b.metric("hyena_channels_pool4_serial_s_L4096", serial);
        b.metric("hyena_channels_pool4_pooled_s_L4096", pooled);
        b.metric("hyena_channels_pool4_speedup_L4096", pool_gate_speedup);

        // Ragged job set: stealing vs static chunking. Channel i convolves
        // length 256·(i+1), so static chunks are badly imbalanced and the
        // self-scheduling claim order should win.
        let rus: Vec<Vec<f64>> = (0..16).map(|i| rng.vec(256 * (i + 1), -1.0, 1.0)).collect();
        let rks: Vec<Vec<f64>> = (0..16).map(|i| rng.vec(256 * (i + 1), -1.0, 1.0)).collect();
        let t_map = b
            .bench("ragged channels: static map 4-thread", || {
                pool4.map(rus.len(), |i| fft_conv_linear(&rus[i], &rks[i]))
            })
            .min;
        let t_steal = b
            .bench("ragged channels: map_stealing 4-thread", || {
                pool4.map_stealing(rus.len(), |i| fft_conv_linear(&rus[i], &rks[i]))
            })
            .min;
        b.metric("ragged_map_s", t_map);
        b.metric("ragged_map_stealing_s", t_steal);
        b.metric("ragged_map_stealing_speedup", t_map / t_steal);
    }

    // --- Resident team vs spawn-per-batch (PR 9) --------------------------
    // The short-batch serve loop is where residency pays: at L=1k each
    // per-channel conv is tens of µs, so a spawn/join per batch is a
    // visible tax that the resident team's park/wake path avoids.
    let team_gate_speedup;
    {
        let l = 1usize << 10;
        let d = 32;
        let pool4 = WorkerPool::new(GATE_POOL_THREADS);
        let us: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let ks: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let t_spawn = b
            .bench("serve loop: spawn-per-batch D=32 L=1K", || {
                pool4.map_spawn(d, |i| fft_conv_linear(&us[i], &ks[i]))
            })
            .min;
        let t_resident = b
            .bench("serve loop: resident team D=32 L=1K", || {
                pool4.map(d, |i| fft_conv_linear(&us[i], &ks[i]))
            })
            .min;
        team_gate_speedup = t_spawn / t_resident;
        b.metric("team_spawn_s_L1024", t_spawn);
        b.metric("team_resident_s_L1024", t_resident);
        b.metric("team_resident_vs_spawn", team_gate_speedup);
    }

    // --- Pooled vs serial: sharded dataflows -------------------------------
    let n = 1 << 18;
    let sa: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let sb = rng.vec(n, -1.0, 1.0);
    let chips = 4;
    let scan_serial = b
        .bench("sharded scan: serial 4 chips 256K", || sharded_mamba_scan(&sa, &sb, chips))
        .min;
    let scan_pooled = b
        .bench("sharded scan: pooled 4 chips 256K", || {
            sharded_mamba_scan_pooled(&sa, &sb, chips, &pool)
        })
        .min;
    b.metric("sharded_scan_serial_s", scan_serial);
    b.metric("sharded_scan_pooled_s", scan_pooled);
    b.metric("sharded_scan_pool_speedup", scan_serial / scan_pooled);

    let xf: Vec<C64> = (0..1 << 14)
        .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
        .collect();
    let fft_serial = b
        .bench("sharded fft: serial 4 chips 16K", || {
            sharded_bailey_fft(&xf, 32, chips, BaileyVariant::Vector)
        })
        .min;
    let fft_pooled = b
        .bench("sharded fft: pooled 4 chips 16K", || {
            sharded_bailey_fft_pooled(&xf, 32, chips, BaileyVariant::Vector, &pool)
        })
        .min;
    b.metric("sharded_fft_serial_s", fft_serial);
    b.metric("sharded_fft_pooled_s", fft_pooled);
    b.metric("sharded_fft_pool_speedup", fft_serial / fft_pooled);

    // --- Pooled vs serial: continuous-batching session sim -----------------
    {
        let cfg = SimConfig::demo(32, 8);
        let d_model = cfg.mamba_shape.d_model;
        let rdu = RduConfig::hs_scan_mode();
        let sim_serial = b
            .bench("session sim: serial 32x8", || {
                let mut exec = MockExecutor::new(1, d_model);
                simulate(&mut exec, &cfg, &rdu).unwrap().tokens
            })
            .min;
        let factory: ExecutorFactory =
            Box::new(move || Ok(Box::new(MockExecutor::new(1, d_model)) as Box<dyn Executor>));
        let threads = pool.threads().min(4);
        let sim_pooled = b
            .bench("session sim: pooled 32x8", || {
                simulate_pooled(&factory, &cfg, &rdu, threads).unwrap().tokens
            })
            .min;
        b.metric("session_sim_serial_s", sim_serial);
        b.metric("session_sim_pooled_s", sim_pooled);
    }

    // --- Scan substrate ---------------------------------------------------
    let v64k = rng.vec(1 << 16, -1.0, 1.0);
    b.bench("scan substrate: c-scan 64K", || c_scan_exclusive(&v64k));
    b.bench("scan substrate: hillis-steele 64K", || hillis_steele_inclusive(&v64k));
    b.bench("scan substrate: blelloch 64K", || blelloch_exclusive(&v64k));
    b.bench("scan substrate: tiled (R=32) 64K", || tiled_exclusive(&v64k, 32));

    // --- PCU simulator ----------------------------------------------------
    let geom = PcuGeometry::table1();
    let prog = pcusim::fft_program(32);
    let batch: Vec<Vec<C64>> = (0..256)
        .map(|_| (0..32).map(|_| C64::real(rng.uniform(-1.0, 1.0))).collect())
        .collect();
    let pcu = Pcu::fft_mode(geom);
    b.bench("pcusim: fft32 spatial x256 vectors", || pcu.run(&prog, &batch));
    let base = Pcu::baseline(geom);
    b.bench("pcusim: fft32 serialized x256 vectors", || base.run(&prog, &batch));

    // --- DFModel pipeline ---------------------------------------------------
    let dc = DecoderConfig::paper(1 << 20);
    let g = hyena_decoder(&dc, BaileyVariant::Vector);
    let cfg = RduConfig::fft_mode();
    b.bench("dfmodel: map+estimate hyena L=1M", || dfmodel::estimate(&g, &cfg).unwrap());

    // --- Coordinator hot path ----------------------------------------------
    let metrics = Metrics::new();
    let mut exec: Box<dyn Executor> = Box::new(MockExecutor::new(4, 1024));
    b.bench("coordinator: pack+dispatch 4x1K batch (mock)", || {
        let (tx, rx) = channel();
        let requests = (0..4)
            .map(|i| (Request::new(i, ModelKind::Mamba, vec![0.5; 1024]), tx.clone()))
            .collect();
        run_batch(exec.as_mut(), Batch { model: ModelKind::Mamba, requests }, &metrics);
        drop(tx);
        black_box(rx.try_iter().count())
    });

    b.metric("conv_gate_speedup_L4096", gate_speedup);
    b.metric("conv_gate_speedup_L16384", gate_speedup_16k);
    b.metric("conv_gate_min_speedup", GATE_MIN_SPEEDUP);
    b.metric("pool_gate_speedup", pool_gate_speedup);
    b.metric("pool_gate_min_speedup", GATE_POOL_MIN_SPEEDUP);
    b.metric("team_gate_min_speedup", GATE_TEAM_MIN_SPEEDUP);
    b.metric("simd_scan_gate_min_speedup", GATE_SIMD_SCAN_MIN_SPEEDUP);
    b.finish();

    // The perf gates (CI fails on regression rather than silently eroding
    // the engine wins): planned real conv must beat the pre-plan naive
    // complex path at both gate lengths, and the 4-thread channel fan-out
    // must beat its serial loop by the pooled floor.
    let mut failed = false;
    for (l, s) in [(GATE_L, gate_speedup), (GATE_L_16K, gate_speedup_16k)] {
        if s < GATE_MIN_SPEEDUP {
            eprintln!(
                "HOT-PATH PERF REGRESSION: planned real conv is only {s:.2}x the naive \
                 complex path at L={l} (gate: >= {GATE_MIN_SPEEDUP}x)"
            );
            failed = true;
        } else {
            println!(
                "hot-path gate OK: planned real conv {s:.2}x naive complex at L={l} \
                 (gate: >= {GATE_MIN_SPEEDUP}x)"
            );
        }
    }
    if pool_gate_speedup < GATE_POOL_MIN_SPEEDUP {
        eprintln!(
            "HOT-PATH PERF REGRESSION: {GATE_POOL_THREADS}-thread channel fan-out is only \
             {pool_gate_speedup:.2}x serial at L={GATE_L} (gate: >= {GATE_POOL_MIN_SPEEDUP}x)"
        );
        failed = true;
    } else {
        println!(
            "hot-path gate OK: {GATE_POOL_THREADS}-thread channel fan-out {pool_gate_speedup:.2}x \
             serial at L={GATE_L} (gate: >= {GATE_POOL_MIN_SPEEDUP}x)"
        );
    }
    if team_gate_speedup < GATE_TEAM_MIN_SPEEDUP {
        eprintln!(
            "HOT-PATH PERF REGRESSION: resident team is only {team_gate_speedup:.2}x \
             spawn-per-batch on the short-batch serve loop (gate: >= {GATE_TEAM_MIN_SPEEDUP}x)"
        );
        failed = true;
    } else {
        println!(
            "hot-path gate OK: resident team {team_gate_speedup:.2}x spawn-per-batch on the \
             short-batch serve loop (gate: >= {GATE_TEAM_MIN_SPEEDUP}x)"
        );
    }
    if simd_backend() == "portable" {
        println!(
            "hot-path gate SKIPPED: simd scan on the portable fallback backend \
             ({simd_scan_speedup:.2}x scalar, not enforced)"
        );
    } else if simd_scan_speedup < GATE_SIMD_SCAN_MIN_SPEEDUP {
        eprintln!(
            "HOT-PATH PERF REGRESSION: simd [{}] mamba scan is only {simd_scan_speedup:.2}x \
             scalar (gate: >= {GATE_SIMD_SCAN_MIN_SPEEDUP}x)",
            simd_backend()
        );
        failed = true;
    } else {
        println!(
            "hot-path gate OK: simd [{}] mamba scan {simd_scan_speedup:.2}x scalar \
             (gate: >= {GATE_SIMD_SCAN_MIN_SPEEDUP}x)",
            simd_backend()
        );
    }
    if failed {
        std::process::exit(1);
    }
}
