//! Perf microbenchmarks: the hot paths of each Rust layer — algorithm
//! substrates, PCU simulator, DFModel pipeline, coordinator batching —
//! tracked across the optimization pass (EXPERIMENTS.md §Perf).

use ssm_rdu::arch::{PcuGeometry, RduConfig};
use ssm_rdu::bench::{black_box, Bencher};
use ssm_rdu::coordinator::{run_batch, Batch, Executor, Metrics, MockExecutor, Request};
use ssm_rdu::dfmodel;
use ssm_rdu::fft::{bailey_fft, fft, to_complex, BaileyVariant};
use ssm_rdu::pcusim::{self, Pcu};
use ssm_rdu::runtime::ModelKind;
use ssm_rdu::scan::{blelloch_exclusive, c_scan_exclusive, hillis_steele_inclusive, tiled_exclusive};
use ssm_rdu::util::{C64, XorShift};
use ssm_rdu::workloads::{hyena_decoder, DecoderConfig};
use std::sync::mpsc::channel;

fn main() {
    let mut b = Bencher::from_env("perf_micro");
    let mut rng = XorShift::new(99);

    // --- FFT substrate ----------------------------------------------------
    let x16k = to_complex(&rng.vec(1 << 14, -1.0, 1.0));
    b.bench("fft substrate: cooley-tukey 16K", || fft(&x16k));
    b.bench("fft substrate: bailey-vector 16K (R=32)", || {
        bailey_fft(&x16k, 32, BaileyVariant::Vector)
    });
    b.bench("fft substrate: bailey-gemm 16K (R=32)", || {
        bailey_fft(&x16k, 32, BaileyVariant::Gemm)
    });

    // --- Scan substrate ---------------------------------------------------
    let v64k = rng.vec(1 << 16, -1.0, 1.0);
    b.bench("scan substrate: c-scan 64K", || c_scan_exclusive(&v64k));
    b.bench("scan substrate: hillis-steele 64K", || hillis_steele_inclusive(&v64k));
    b.bench("scan substrate: blelloch 64K", || blelloch_exclusive(&v64k));
    b.bench("scan substrate: tiled (R=32) 64K", || tiled_exclusive(&v64k, 32));

    // --- PCU simulator ----------------------------------------------------
    let geom = PcuGeometry::table1();
    let prog = pcusim::fft_program(32);
    let batch: Vec<Vec<C64>> = (0..256)
        .map(|_| (0..32).map(|_| C64::real(rng.uniform(-1.0, 1.0))).collect())
        .collect();
    let pcu = Pcu::fft_mode(geom);
    b.bench("pcusim: fft32 spatial x256 vectors", || pcu.run(&prog, &batch));
    let base = Pcu::baseline(geom);
    b.bench("pcusim: fft32 serialized x256 vectors", || base.run(&prog, &batch));

    // --- DFModel pipeline ---------------------------------------------------
    let dc = DecoderConfig::paper(1 << 20);
    let g = hyena_decoder(&dc, BaileyVariant::Vector);
    let cfg = RduConfig::fft_mode();
    b.bench("dfmodel: map+estimate hyena L=1M", || dfmodel::estimate(&g, &cfg).unwrap());

    // --- Coordinator hot path ----------------------------------------------
    let metrics = Metrics::new();
    let mut exec: Box<dyn Executor> = Box::new(MockExecutor::new(4, 1024));
    b.bench("coordinator: pack+dispatch 4x1K batch (mock)", || {
        let (tx, rx) = channel();
        let requests = (0..4)
            .map(|i| (Request::new(i, ModelKind::Mamba, vec![0.5; 1024]), tx.clone()))
            .collect();
        run_batch(exec.as_mut(), Batch { model: ModelKind::Mamba, requests }, &metrics);
        drop(tx);
        black_box(rx.try_iter().count())
    });

    b.finish();
}
