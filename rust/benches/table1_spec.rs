//! Bench E1 — Table I: the RDU architectural specification, plus the
//! derived peak-throughput arithmetic that Tables II/III rest on.

use ssm_rdu::arch::RduSpec;
use ssm_rdu::bench::Bencher;
use ssm_rdu::figures;

fn main() {
    let mut b = Bencher::from_env("table1_spec");
    b.report("TABLE I (paper) vs model", || figures::table1().print());
    b.report("derived peak arithmetic", || {
        let spec = RduSpec::table1();
        println!(
            "  {} PCUs x {} FUs x 2 flop x {:.1} GHz = {:.2} TFLOPS (paper: 638.98, \"640\")",
            spec.n_pcu,
            spec.pcu.fu_count(),
            spec.clock_hz / 1e9,
            spec.peak_flops() / 1e12
        );
        println!(
            "  on-chip SRAM: {} PMUs x {:.1} MB = {:.0} MB",
            spec.n_pmu,
            spec.pmu_bytes as f64 / (1 << 20) as f64,
            spec.sram_bytes() as f64 / (1 << 20) as f64
        );
        assert!((spec.peak_flops() / 1e12 - 638.98).abs() < 0.01);
    });
    b.bench("RduSpec::table1 construction", RduSpec::table1);
    b.finish();
}
