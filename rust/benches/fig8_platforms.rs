//! Bench E3 — Table II + Figure 8: the Hyena decoder across GPU, VGA and
//! FFT-mode RDU, with paper-vs-measured speedups.

use ssm_rdu::arch::GpuSpec;
use ssm_rdu::bench::Bencher;
use ssm_rdu::fft::BaileyVariant;
use ssm_rdu::figures::platforms::{fig8, table2};
use ssm_rdu::gpu;
use ssm_rdu::workloads::{hyena_decoder, DecoderConfig};

fn main() {
    let mut b = Bencher::from_env("fig8_platforms");
    b.report("TABLE II (platform specs)", || table2().print());
    let f = b.report("Fig. 8 dataset (three platforms, paper sweep)", fig8);
    f.table().print();
    f.speedup_report().print();

    let dc = DecoderConfig::paper(1 << 20);
    let g = hyena_decoder(&dc, BaileyVariant::Vector);
    let spec = GpuSpec::a100();
    b.bench("gpu::estimate hyena (L=1M)", || gpu::estimate(&g, &spec));
    b.finish();
}
