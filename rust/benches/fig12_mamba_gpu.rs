//! Bench E5 — Table III + Figure 12: parallel-scan Mamba on the A100 GPU
//! vs the scan-mode RDU (paper: 2.12×).

use ssm_rdu::arch::{GpuSpec, RduSpec};
use ssm_rdu::bench::Bencher;
use ssm_rdu::figures::mamba::fig12;
use ssm_rdu::util::table::Table;

fn table3() -> Table {
    let g = GpuSpec::a100();
    let r = RduSpec::table1();
    let mut t = Table::new(
        "TABLE III — architectural specifications of two accelerators",
        &["", "GPU", "Scan RDU"],
    );
    t.row(&[
        "GEMM FP16 TFLOPS".into(),
        format!("{:.2}", g.tensor_flops / 1e12),
        format!("{:.2}", r.peak_flops() / 1e12),
    ]);
    t.row(&[
        "Scan FP16 TFLOPS".into(),
        format!("{:.2}", g.cuda_flops / 1e12),
        format!("{:.2}", r.peak_flops() / 1e12),
    ]);
    t
}

fn main() {
    let mut b = Bencher::from_env("fig12_mamba_gpu");
    b.report("TABLE III (platform specs)", || table3().print());
    let f = b.report("Fig. 12 dataset (GPU vs scan-mode RDU, L=1M)", fig12);
    f.table().print();
    f.speedup_report().print();
    b.finish();
}
