//! Bench E2 — Figure 7: FLOP count and latency of the four Hyena-side
//! designs (attention, Vector-FFT/baseline, GEMM-FFT/baseline,
//! Vector-FFT/FFT-mode) across L ∈ {256K, 512K, 1M}, with paper-vs-measured
//! speedups. Also times the DFModel estimation pipeline itself.

use ssm_rdu::arch::RduConfig;
use ssm_rdu::bench::Bencher;
use ssm_rdu::dfmodel;
use ssm_rdu::fft::BaileyVariant;
use ssm_rdu::figures::hyena::fig7;
use ssm_rdu::workloads::{hyena_decoder, DecoderConfig};

fn main() {
    let mut b = Bencher::from_env("fig7_hyena");

    let f = b.report("Fig. 7 dataset (DFModel, paper sweep)", fig7);
    f.table().print();
    f.speedup_report().print();

    // Time the modeling pipeline (the thing a DFModel user iterates on).
    let dc = DecoderConfig::paper(1 << 20);
    let cfg = RduConfig::fft_mode();
    b.bench("build hyena graph (L=1M)", || hyena_decoder(&dc, BaileyVariant::Vector));
    let g = hyena_decoder(&dc, BaileyVariant::Vector);
    b.bench("dfmodel::estimate hyena (L=1M)", || dfmodel::estimate(&g, &cfg).unwrap());
    b.finish();
}
