//! Fleet scaling gate (`BENCH_fleet.json`): a 2-node fleet must deliver at
//! least the goodput of one node on the same offered load — otherwise the
//! router, migration machinery, or per-node batching regressed into
//! negative scaling.
//!
//! Method: calibrate one node's token capacity under full overload, offer a
//! Poisson trace at 1.2× that capacity (so a single node saturates and
//! queues, while two nodes have headroom), set the SLO to the single-node
//! overload p50, and compare goodput — SLO-meeting tokens per modeled
//! second — at 1 node vs 2 nodes. All quantities are modeled time, so the
//! gate is machine-independent and deterministic; wall-clock `bench()`
//! numbers are recorded informationally for the perf trajectory.

use ssm_rdu::bench::{black_box, Bencher};
use ssm_rdu::fleet::{
    calibrate_single_node, generate, mock_factory, run_fleet, FleetConfig, FleetScenario,
    TraceConfig,
};

/// CI gate: 2-node goodput must be ≥ this multiple of 1-node goodput.
const GATE_MIN_SCALING: f64 = 1.0;

fn main() {
    let mut b = Bencher::from_env("fleet");
    let sessions = 64;
    let seed = 7;
    let factory = mock_factory();
    let base_cfg = FleetConfig::demo(1, 2);

    // Calibrate: one node's capacity and overload p50 set the offered rate
    // and the SLO (scale-free against the modeled step costs).
    let probe_cfg = TraceConfig::poisson(sessions, 1.0, seed);
    let (node_tok_s, p50_us) =
        calibrate_single_node(&base_cfg, &generate(&probe_cfg), &factory).expect("calibration");
    assert!(node_tok_s > 0.0 && p50_us > 0.0);
    b.metric("calibrated_node_tok_s", node_tok_s);
    b.metric("calibrated_p50_us", p50_us);

    let rate = 1.2 * node_tok_s / probe_cfg.mean_decode_tokens();
    let trace = generate(&TraceConfig::poisson(sessions, rate, seed));

    let run_nodes = |nodes: usize| {
        let mut cfg = FleetConfig::demo(nodes, 2);
        cfg.slo_us = p50_us;
        run_fleet(&cfg, &trace, &FleetScenario::default(), &factory).expect("fleet run")
    };

    // Wall-clock cost of simulating the fleet (informational only — the
    // gate compares modeled goodput, not host time).
    b.bench("simulate_1node_wall", || {
        black_box(run_nodes(1));
    });
    b.bench("simulate_2node_wall", || {
        black_box(run_nodes(2));
    });

    let r1 = run_nodes(1);
    let r2 = run_nodes(2);
    assert_eq!(r1.completed, sessions as u64, "1-node run must complete");
    assert_eq!(r2.completed, sessions as u64, "2-node run must complete");

    let scaling =
        if r1.goodput_tok_s > 0.0 { r2.goodput_tok_s / r1.goodput_tok_s } else { f64::INFINITY };
    b.metric("goodput_1node_tok_s", r1.goodput_tok_s);
    b.metric("goodput_2node_tok_s", r2.goodput_tok_s);
    b.metric("throughput_1node_tok_s", r1.throughput_tok_s);
    b.metric("throughput_2node_tok_s", r2.throughput_tok_s);
    b.metric("slo_attainment_1node", r1.slo_attainment);
    b.metric("slo_attainment_2node", r2.slo_attainment);
    b.metric("p99_us_1node", r1.p99_us);
    b.metric("p99_us_2node", r2.p99_us);
    b.metric("goodput_scaling_2node", scaling);
    b.metric("gate_min_scaling", GATE_MIN_SCALING);

    // Write BENCH_fleet.json before the verdict so a failure still leaves
    // the numbers on disk for the perf-trajectory artifact.
    b.finish();

    if scaling < GATE_MIN_SCALING {
        eprintln!(
            "FLEET SCALING REGRESSION: 2-node goodput {:.0} tok/s is {:.2}x the 1-node \
             {:.0} tok/s (gate ≥ {:.2}x) at 1.2x single-node offered load, SLO {:.2} us",
            r2.goodput_tok_s, scaling, r1.goodput_tok_s, GATE_MIN_SCALING, p50_us
        );
        std::process::exit(1);
    }
    println!(
        "fleet gate OK: 2-node goodput {:.0} tok/s = {:.2}x 1-node {:.0} tok/s \
         (gate ≥ {:.2}x; SLO {:.2} us, attainment {:.1}% -> {:.1}%)",
        r2.goodput_tok_s,
        scaling,
        r1.goodput_tok_s,
        GATE_MIN_SCALING,
        p50_us,
        r1.slo_attainment * 100.0,
        r2.slo_attainment * 100.0,
    );
}
