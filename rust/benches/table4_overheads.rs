//! Bench E6 — Table IV: area/power overheads of the enhanced PCUs from the
//! 45 nm synthesis model, plus the route-count ablation across geometries.

use ssm_rdu::arch::{PcuGeometry, PcuMode};
use ssm_rdu::bench::Bencher;
use ssm_rdu::figures::table4;
use ssm_rdu::pcusim::topology;
use ssm_rdu::synth;

fn main() {
    let mut b = Bencher::from_env("table4_overheads");
    b.report("TABLE IV (model vs paper)", || table4().print());

    b.report("route-count ablation (mux additions per geometry)", || {
        println!("  geometry   fft  hs-scan  b-scan");
        for geom in [PcuGeometry::synthesis(), PcuGeometry::new(16, 8), PcuGeometry::table1()] {
            println!(
                "  {:8} {:5} {:8} {:7}",
                geom.to_string(),
                topology::added_mux_count(PcuMode::Fft, geom),
                topology::added_mux_count(PcuMode::HsScan, geom),
                topology::added_mux_count(PcuMode::BScan, geom),
            );
        }
    });

    b.report("production-PCU (32x12) overhead projection", || {
        let geom = PcuGeometry::table1();
        for mode in [PcuMode::Fft, PcuMode::HsScan, PcuMode::BScan] {
            let s = synth::synthesize(geom, Some(mode));
            println!(
                "  {:8} area {:.1} µm² ({:.3}x)  power {:.1} mW ({:.3}x)",
                mode.label(),
                s.area_um2,
                s.area_ratio(),
                s.power_mw,
                s.power_ratio()
            );
        }
    });

    b.bench("synthesize all four variants", synth::table4_rows);
    b.finish();
}
