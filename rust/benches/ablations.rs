//! Ablation benches for the design choices DESIGN.md calls out:
//!   * chip scale (PCU count) — where the extensions' gains saturate;
//!   * memory technology — when the dataflow pipeline goes memory-bound;
//!   * pipeline depth — the serialized penalty (1/stages) vs spatial
//!     factor (levels/stages) trade the paper's §III-B argument rests on;
//!   * Bailey tile size R — the §III-A FLOP-vs-hardware trade-off;
//!   * Mamba state shape — paper scalar-state vs full selective SSM;
//!   * energy per inference — Table IV's power story carried to its
//!     end-to-end conclusion.

use ssm_rdu::arch::{MemTech, RduConfig};
use ssm_rdu::bench::Bencher;
use ssm_rdu::dfmodel::{self, sweep};
use ssm_rdu::fft::{gemm_fft_flops, vector_fft_flops, BaileyVariant};
use ssm_rdu::synth::energy;
use ssm_rdu::util::fmt_time;
use ssm_rdu::util::table::Table;
use ssm_rdu::workloads::{hyena_decoder, mamba_decoder, ssm_workloads, DecoderConfig, ScanVariant};

fn main() {
    let mut b = Bencher::from_env("ablations");
    let dc = DecoderConfig::paper(1 << 20);
    // All registered SSM workloads (hyena, mamba, ssd, s4) ride every sweep.
    let wls = ssm_workloads();

    b.report("ablation: chip scale (PCU count)", || {
        sweep::sweep_table(
            "chip scale @ L=1M",
            &sweep::sweep_pcu_count(&dc, &[65, 130, 260, 520, 1040], &wls),
        )
        .print()
    });

    b.report("ablation: memory technology", || {
        sweep::sweep_table(
            "off-chip bandwidth @ L=1M",
            &sweep::sweep_bandwidth(&dc, &[MemTech::Ddr5, MemTech::Hbm2e, MemTech::Hbm3e], &wls),
        )
        .print()
    });

    b.report("ablation: pipeline depth (stages)", || {
        sweep::sweep_table(
            "pipeline depth @ L=1M",
            &sweep::sweep_stages(&dc, &[6, 8, 12, 16, 24], &wls),
        )
        .print()
    });

    b.report("ablation: Bailey tile size R (transform FLOPs)", || {
        let mut t = Table::new(
            "GEMM-FFT FLOP overhead vs R (paper §III-A: R/log2R)",
            &["R", "overhead"],
        );
        let l = 1 << 21;
        for r in [8usize, 16, 32, 64, 128] {
            t.row(&[r.to_string(), format!("{:.2}x", gemm_fft_flops(l, r) / vector_fft_flops(l))]);
        }
        t.print()
    });

    b.report("ablation: Mamba state shape", || {
        let mut t = Table::new(
            "Mamba shape ablation @ L=1M",
            &["shape", "baseline RDU", "scan-mode RDU", "gain"],
        );
        for (name, cfg) in [
            ("paper scalar-state (C=32)", DecoderConfig::paper(1 << 20)),
            ("selective SSM (N=16, E=2)", DecoderConfig::mamba_full(1 << 20)),
        ] {
            let g = mamba_decoder(&cfg, ScanVariant::Parallel);
            let e0 = dfmodel::estimate(&g, &RduConfig::baseline()).unwrap().total_seconds;
            let e1 = dfmodel::estimate(&g, &RduConfig::hs_scan_mode()).unwrap().total_seconds;
            t.row(&[name.to_string(), fmt_time(e0), fmt_time(e1), format!("{:.2}x", e0 / e1)]);
        }
        t.print()
    });

    b.report("ablation: energy per inference", || {
        let mut t = Table::new(
            "energy per decoder pass @ L=1M (chip power x latency + DRAM)",
            &["workload", "baseline RDU", "extended RDU", "energy gain", "power overhead"],
        );
        let hy = hyena_decoder(&dc, BaileyVariant::Vector);
        let ma = mamba_decoder(&dc, ScanVariant::Parallel);
        for (name, g, ext, mode) in [
            ("hyena", &hy, RduConfig::fft_mode(), ssm_rdu::arch::PcuMode::Fft),
            ("mamba", &ma, RduConfig::hs_scan_mode(), ssm_rdu::arch::PcuMode::HsScan),
        ] {
            let base = RduConfig::baseline();
            let io = g.external_input_bytes() + g.external_output_bytes() + g.total_weight_bytes();
            let e0 = energy::inference_energy(&base, &dfmodel::estimate(g, &base).unwrap(), io);
            let e1 = energy::inference_energy(&ext, &dfmodel::estimate(g, &ext).unwrap(), io);
            t.row(&[
                name.to_string(),
                format!("{:.2} mJ", e0 * 1e3),
                format!("{:.2} mJ", e1 * 1e3),
                format!("{:.2}x", e0 / e1),
                format!("{:.3}x", energy::extension_power_overhead(mode)),
            ]);
        }
        t.print()
    });

    b.finish();
}
