//! Session-serving benchmarks: the continuous-batching scheduler + state
//! cache driven end-to-end (MockExecutor numerics, DFModel decode-cost
//! timing) across session counts and cache budgets — the hot path of
//! `serve --continuous`.

use ssm_rdu::arch::RduConfig;
use ssm_rdu::bench::Bencher;
use ssm_rdu::coordinator::MockExecutor;
use ssm_rdu::session::{simulate, SimConfig};

fn scenario(sessions: usize, decode_steps: usize, budget_frac: f64) -> SimConfig {
    let mut cfg = SimConfig::demo(sessions, decode_steps);
    cfg.budget_bytes = (cfg.footprint_bytes() as f64 * budget_frac) as usize;
    cfg
}

fn main() {
    let mut b = Bencher::from_env("serve_sessions");
    let rdu = RduConfig::hs_scan_mode();

    for &(sessions, frac) in &[(16usize, 1.0f64), (16, 0.25), (64, 1.0), (64, 0.25)] {
        let cfg = scenario(sessions, 8, frac);
        let name = format!(
            "continuous: {sessions} sessions × 8 tokens, budget {:.0}%",
            frac * 100.0
        );
        b.bench(&name, || {
            let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
            simulate(&mut exec, &cfg, &rdu).expect("simulation completes")
        });
    }

    // Scheduler-only pressure: wide batches over many tiny sessions.
    let cfg = scenario(256, 4, 0.5);
    b.bench("continuous: 256 sessions × 4 tokens, budget 50%", || {
        let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
        simulate(&mut exec, &cfg, &rdu).expect("simulation completes")
    });

    // One-line throughput report at the demo scale.
    let cfg = scenario(64, 16, 0.5);
    let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
    let r = simulate(&mut exec, &cfg, &rdu).expect("simulation completes");
    println!(
        "64 sessions × 16 tokens @ 50% budget: {} tokens, modeled {:.2e} tok/s, \
         mean batch {:.1}, evictions {}, hit rate {:.1}%",
        r.tokens,
        r.tokens_per_sim_second(),
        r.mean_batch,
        r.cache.evictions,
        r.cache.hit_rate() * 100.0,
    );

    b.finish();
}
