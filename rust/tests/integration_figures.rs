//! Figure-level integration: every paper table/figure regenerates, the
//! orderings hold, the headline speedups land in their reproduction bands,
//! and the ratios are stable across the sweep (as the paper claims).
//!
//! Bands are intentionally generous — our substrate is a reimplementation
//! of DFModel, not the authors' binary; EXPERIMENTS.md records the exact
//! paper-vs-measured deltas of each run.

use ssm_rdu::figures::{hyena, mamba, overheads, platforms};

// Shorter sweep than the paper's for test time; the benches run the full
// 256K/512K/1M sweep.
const LENS: [usize; 2] = [1 << 18, 1 << 20];

#[test]
fn fig7_reproduces_shape_and_bands() {
    let f = hyena::fig7_at(&LENS);
    // Ordering at every length.
    for &l in &LENS {
        let d: Vec<f64> = (0..4).map(|i| f.latency(i, l)).collect();
        assert!(d[0] > d[1] && d[1] > d[2] && d[2] > d[3], "L={l}: {d:?}");
    }
    // Bands: D1→D2 paper 217.74× (accept 50–1000×), D2→D3 paper 2.61×
    // (accept 1.2–6×), D3→D4 paper 1.95× (accept 1.2–6×).
    let s: Vec<f64> = f.speedups.iter().map(|r| r.measured).collect();
    assert!(s[0] > 50.0 && s[0] < 1000.0, "D1/D2={}", s[0]);
    assert!(s[1] > 1.2 && s[1] < 6.0, "D2/D3={}", s[1]);
    assert!(s[2] > 1.2 && s[2] < 6.0, "D3/D4={}", s[2]);
}

#[test]
fn fig7_speedups_stable_across_lengths() {
    // Paper: "achieves a 1.95× speedup … across different sequence lengths".
    let a = hyena::fig7_at(&[1 << 18]);
    let b = hyena::fig7_at(&[1 << 20]);
    for (ra, rb) in a.speedups.iter().zip(&b.speedups) {
        if ra.label.contains("design 2 over design 1") {
            continue; // the attention ratio scales with L by construction
        }
        let drift = (ra.measured / rb.measured - 1.0).abs();
        assert!(drift < 0.10, "{}: {} vs {}", ra.label, ra.measured, rb.measured);
    }
}

#[test]
fn fig8_reproduces_shape_and_bands() {
    let f = platforms::fig8_at(&LENS);
    for r in &f.rows {
        assert!(r.gpu > r.rdu, "{}: GPU must lose", r.variant);
    }
    let by_label = |needle: &str| {
        f.speedups
            .iter()
            .find(|s| s.label.contains(needle))
            .unwrap_or_else(|| panic!("{needle}"))
            .measured
    };
    // Paper: gemm-fft 2×, vector-fft 5.95×, VGA ≈ RDU.
    let gemm = by_label("gemm-fft: RDU over GPU");
    let vec = by_label("vector-fft: RDU over GPU");
    let parity = by_label("VGA over RDU");
    assert!(gemm > 1.3 && gemm < 6.0, "gemm={gemm}");
    assert!(vec > 3.0 && vec < 12.0, "vec={vec}");
    assert!(vec > gemm, "the vector-FFT gap is the bigger one");
    assert!((parity - 1.0).abs() < 0.35, "parity={parity}");
}

#[test]
fn fig11_reproduces_shape_and_bands() {
    let f = mamba::fig11_at(&LENS);
    for &l in &LENS {
        let d: Vec<f64> = (0..5).map(|i| f.latency(i, l)).collect();
        assert!(d[0] > d[1] && d[1] > d[2] && d[2] > d[3], "L={l}: {d:?}");
        // HS-mode ≡ B-mode (paper: identical performance).
        assert!((d[3] - d[4]).abs() / d[3] < 0.01, "L={l}: {d:?}");
    }
    let s: Vec<f64> = f.speedups.iter().map(|r| r.measured).collect();
    // Paper bands: 7.34× (accept 2–40), 562.98× (accept 100–2000),
    // 1.75× (accept 1.05–4), parity ≈ 1.
    assert!(s[0] > 2.0 && s[0] < 40.0, "D1/D2={}", s[0]);
    assert!(s[1] > 100.0 && s[1] < 2000.0, "D2/D3={}", s[1]);
    assert!(s[2] > 1.05 && s[2] < 4.0, "D3/D4={}", s[2]);
    assert!((s[3] - 1.0).abs() < 0.01, "D4/D5={}", s[3]);
}

#[test]
fn fig12_reproduces_band() {
    let f = mamba::fig12_at(1 << 20);
    assert!(f.rdu_latency < f.gpu_latency);
    // Paper 2.12×; our GPU model includes kernel-by-kernel staging the
    // paper appears to omit, so accept 1.5–12× (compute-only lands closer).
    let full = f.speedups[0].measured;
    let compute_only = f.speedups[1].measured;
    assert!(full > 1.5 && full < 12.0, "full={full}");
    assert!(compute_only > 1.2 && compute_only < 6.0, "compute={compute_only}");
}

#[test]
fn table4_reproduces_within_tenth_percent() {
    let rows = overheads::table4_rows();
    let paper = [(90_899.1, 140.7), (91_572.9, 141.4), (91_383.0, 141.2), (91_275.7, 141.1)];
    for (row, (pa, pp)) in rows.iter().zip(paper) {
        assert!((row.area_um2 - pa).abs() / pa < 1e-3, "{:?}: {}", row.mode, row.area_um2);
        assert!((row.power_mw - pp).abs() / pp < 1e-3, "{:?}: {}", row.mode, row.power_mw);
        assert!(row.area_ratio() < 1.01 && row.power_ratio() < 1.01);
    }
}

#[test]
fn all_reports_render_nonempty() {
    let f7 = hyena::fig7_at(&[1 << 18]);
    let f8 = platforms::fig8_at(&[1 << 18]);
    let f11 = mamba::fig11_at(&[1 << 18]);
    let f12 = mamba::fig12_at(1 << 18);
    for s in [
        f7.table().render(),
        f7.speedup_report().render(),
        f8.table().render(),
        f8.speedup_report().render(),
        f11.table().render(),
        f11.speedup_report().render(),
        f12.table().render(),
        f12.speedup_report().render(),
        overheads::table4().render(),
        ssm_rdu::figures::table1().render(),
        platforms::table2().render(),
    ] {
        assert!(s.lines().count() > 3, "{s}");
    }
}
