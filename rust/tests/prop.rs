//! Differential property-test harness for the serving hot loop (PR 7's
//! lock-down suite): every chunked / SIMD / pooled / sharded / planned
//! fast path is fuzzed against its scalar oracle over adversarial shapes —
//! lengths
//! around the chunk width (1..=17), around the plan-cache watershed
//! (1024 ± 1), around the split-radix watershed (16384 ± 1), non-powers of
//! two, ragged channel sets, and arbitrary chip counts.
//!
//! The harness is `ssm_rdu::util::prop`: a dependency-free seeded runner
//! (xorshift64*) with greedy shrinking, so failures print a *minimal*
//! counterexample and reproduce exactly. CI pins the default seed; set
//! `SSM_RDU_PROP_SEED=<u64>` to explore a different corner of the input
//! space locally (documented in docs/WORKLOADS.md).
//!
//! Since the `define_pcu_program!` migration this file also fuzzes the
//! pcusim DSL: random stage chains whose cross-lane routes are admitted by
//! `topology::allows` must build through `ProgramBuilder`, execute
//! identically to a straight-line scalar reference on both fabrics, and
//! single-step through the debugger to the same outputs and stats.

use ssm_rdu::arch::{PcuGeometry, PcuMode};
use ssm_rdu::fft::conv::{direct_conv_circular, direct_conv_linear};
use ssm_rdu::fft::{
    fft_conv_linear, fft_conv_linear_channels, fft_conv_linear_naive, FftEngine, FftPlan,
    RealFftPlan,
};
use ssm_rdu::pcusim::dsl::ops;
use ssm_rdu::pcusim::program::Op;
use ssm_rdu::pcusim::{topology, DebugSession, Pcu, ProgramBuilder};
use ssm_rdu::runtime::{StealQueues, WorkerPool};
use ssm_rdu::scan::{
    gate_silu_chunked, gate_silu_scalar, gate_silu_simd, mamba_scan_channels_chunked,
    mamba_scan_channels_scalar, mamba_scan_channels_simd, mamba_scan_serial,
    scan_gate_channels_chunked, scan_gate_channels_scalar, scan_gate_channels_simd,
    silu_slice_chunked, silu_slice_scalar,
};
use ssm_rdu::shard::{sharded_mamba_scan, sharded_mamba_scan_pooled};
use ssm_rdu::util::prop::{check, no_shrink, Config};
use ssm_rdu::util::{max_abs_diff, C64, XorShift};
use ssm_rdu::workloads::{s4_kernel_chunked, s4_kernel_scalar, s4_kernel_simd};

/// Property-run config: the seed comes from `SSM_RDU_PROP_SEED` when set
/// (so CI can pin it and a developer can sweep it), else the harness
/// default.
fn cfg(cases: usize) -> Config {
    let mut c = Config { cases, ..Config::default() };
    if let Some(seed) =
        std::env::var("SSM_RDU_PROP_SEED").ok().and_then(|v| v.parse::<u64>().ok())
    {
        c.seed = seed;
    }
    c
}

/// Lengths the chunked and planned paths are most likely to get wrong:
/// everything around one SIMD chunk, the two cache watersheds ± 1, and a
/// random non-power-of-two filler.
fn interesting_len(rng: &mut XorShift) -> usize {
    const EDGES: &[usize] = &[
        1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 1023, 1024, 1025, 16383,
        16384, 16385,
    ];
    if rng.below(2) == 0 {
        *rng.choose(EDGES)
    } else {
        rng.range(1, 2048)
    }
}

/// Shrink a (len-driven) generated case by halving its vectors together.
fn shrink_ab(case: &(Vec<f64>, Vec<f64>)) -> Vec<(Vec<f64>, Vec<f64>)> {
    let n = case.0.len();
    if n <= 1 {
        return Vec::new();
    }
    vec![
        (case.0[..n / 2].to_vec(), case.1[..n / 2].to_vec()),
        (case.0[n / 2..].to_vec(), case.1[n / 2..].to_vec()),
    ]
}

// ---------------------------------------------------------------- chunked

#[test]
fn prop_silu_and_gate_chunked_bit_identical_to_scalar() {
    check(
        &cfg(96),
        "silu/gate chunked == scalar",
        |r| {
            let n = interesting_len(r);
            (r.vec(n, -4.0, 4.0), r.vec(n, -4.0, 4.0))
        },
        shrink_ab,
        |(h, z)| {
            if silu_slice_chunked(z) != silu_slice_scalar(z) {
                return Err("silu_slice_chunked diverged".into());
            }
            if gate_silu_chunked(h, z) != gate_silu_scalar(h, z) {
                return Err("gate_silu_chunked diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mamba_scan_channels_chunked_bit_identical_to_scalar() {
    // The channel axis carries no dependency, so chunking reorders nothing:
    // the lockstep per-channel recurrences must match the scalar loop bit
    // for bit at every (T, C) — including C not a multiple of the lane
    // width and T around the edge set.
    check(
        &cfg(64),
        "mamba_scan_channels chunked == scalar",
        |r| {
            let t = interesting_len(r).min(2048);
            let c = r.range(1, 9);
            (r.vec(t * c, -0.99, 0.99), r.vec(t * c, -1.0, 1.0), c)
        },
        no_shrink,
        |(a, b, c)| {
            let got = mamba_scan_channels_chunked(a, b, *c);
            let want = mamba_scan_channels_scalar(a, b, *c);
            if got != want {
                return Err(format!("diverged at C={c}, T={}", a.len() / c));
            }
            let gated_got = scan_gate_channels_chunked(a, b, b, *c);
            let gated_want = scan_gate_channels_scalar(a, b, b, *c);
            if gated_got != gated_want {
                return Err(format!("gated scan diverged at C={c}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_s4_kernel_chunked_within_reassociation_budget() {
    // Mode-block chunking reassociates the mode sum, so bit-identity is not
    // on the table; the documented contract is ≤1e-9 against the scalar
    // oracle (see workloads::s4).
    check(
        &cfg(48),
        "s4_kernel chunked ~ scalar (1e-9)",
        |r| {
            let modes = r.range(1, 18);
            let l = interesting_len(r).min(1024);
            (r.vec(modes, -0.99, -0.01), r.vec(modes, -1.0, 1.0), l)
        },
        no_shrink,
        |(lambda, c, l)| {
            let d =
                max_abs_diff(&s4_kernel_chunked(lambda, c, *l), &s4_kernel_scalar(lambda, c, *l));
            if d <= 1e-9 {
                Ok(())
            } else {
                Err(format!("diff {d:e} at modes={}, L={l}", lambda.len()))
            }
        },
    );
}

// ------------------------------------------------------------------ simd

#[test]
fn prop_simd_kernels_bit_identical_to_scalar() {
    // The explicit-lane kernels (scan::simd) carry the same contract as
    // the chunked twins: no FMA, lanes never mix, transcendentals stay
    // scalar — so on *every* backend (avx / neon / portable) the outputs
    // must be byte-equal to the scalar oracles at any (T, C).
    check(
        &cfg(64),
        "simd scan/gate == scalar",
        |r| {
            let t = interesting_len(r).min(2048);
            let c = r.range(1, 9);
            (r.vec(t * c, -0.99, 0.99), r.vec(t * c, -1.0, 1.0), c)
        },
        no_shrink,
        |(a, b, c)| {
            if mamba_scan_channels_simd(a, b, *c) != mamba_scan_channels_scalar(a, b, *c) {
                return Err(format!("simd scan diverged at C={c}, T={}", a.len() / c));
            }
            if scan_gate_channels_simd(a, b, b, *c) != scan_gate_channels_scalar(a, b, b, *c) {
                return Err(format!("simd gated scan diverged at C={c}"));
            }
            if gate_silu_simd(a, b) != gate_silu_scalar(a, b) {
                return Err("simd gate diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_s4_kernel_simd_exactly_chunked_within_budget_of_scalar() {
    // The SIMD s4 kernel keeps the chunked kernel's pairwise association
    // exactly (its horizontal adds reduce (t0+t1)+(t2+t3) in the same
    // order), so it is bit-identical to chunked — and therefore inherits
    // chunked's documented ≤1e-9 budget against the scalar oracle.
    check(
        &cfg(48),
        "s4 simd == chunked, ~ scalar (1e-9)",
        |r| {
            let modes = r.range(1, 18);
            let l = interesting_len(r).min(1024);
            (r.vec(modes, -0.99, -0.01), r.vec(modes, -1.0, 1.0), l)
        },
        no_shrink,
        |(lambda, c, l)| {
            let simd = s4_kernel_simd(lambda, c, *l);
            if simd != s4_kernel_chunked(lambda, c, *l) {
                return Err(format!("simd != chunked at modes={}, L={l}", lambda.len()));
            }
            let d = max_abs_diff(&simd, &s4_kernel_scalar(lambda, c, *l));
            if d <= 1e-9 {
                Ok(())
            } else {
                Err(format!("diff {d:e} vs scalar at modes={}, L={l}", lambda.len()))
            }
        },
    );
}

// ------------------------------------------------------------------ FFT

#[test]
fn prop_blocked_fft_traversal_bit_identical_to_flat() {
    // The cache-blocked traversal reorders butterflies across *independent*
    // halves only — same twiddles, same pairing, same order within each
    // butterfly — so it must be exactly the breadth-first result, for any
    // power-of-two length and any power-of-two base block.
    check(
        &cfg(48),
        "blocked FFT == flat FFT (bit-identical)",
        |r| {
            let n = 1usize << r.range(1, 12);
            let base = 1usize << r.range(1, 11);
            (r.vec(2 * n, -1.0, 1.0), base)
        },
        no_shrink,
        |(re_im, base)| {
            let n = re_im.len() / 2;
            let plan = FftPlan::new(n);
            let x: Vec<C64> =
                (0..n).map(|i| C64::new(re_im[2 * i], re_im[2 * i + 1])).collect();
            let mut flat = x.clone();
            plan.fft_in_place_flat(&mut flat);
            let mut blocked = x;
            plan.fft_in_place_blocked(&mut blocked, *base);
            if flat != blocked {
                return Err(format!("traversals diverged at n={n}, base={base}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_radix_engine_matches_radix2_engine() {
    // Split-radix uses a different butterfly grouping, so agreement is
    // analytic, not bit-level: ≤1e-9 between engines on the packed forward
    // spectrum and ≤1e-10 on the roundtrip.
    check(
        &cfg(24),
        "split-radix ~ radix-2 (1e-9)",
        |r| (1usize << r.range(3, 13), r.next_u64()),
        no_shrink,
        |&(n, seed)| {
            let mut rng = XorShift::new(seed);
            let x = rng.vec(n, -1.0, 1.0);
            let mut sr = RealFftPlan::with_engine(n, FftEngine::SplitRadix);
            let mut r2 = RealFftPlan::with_engine(n, FftEngine::Radix2);
            let mut spec_sr = vec![C64::ZERO; n / 2 + 1];
            let mut spec_r2 = vec![C64::ZERO; n / 2 + 1];
            sr.rfft_into(&x, &mut spec_sr);
            r2.rfft_into(&x, &mut spec_r2);
            let worst = spec_sr
                .iter()
                .zip(&spec_r2)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0f64, f64::max);
            if worst > 1e-9 {
                return Err(format!("spectra diverged by {worst:e} at n={n}"));
            }
            let mut back = vec![0.0; n];
            sr.irfft_into(&spec_sr, &mut back);
            let rt = max_abs_diff(&back, &x);
            if rt > 1e-10 {
                return Err(format!("split-radix roundtrip err {rt:e} at n={n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_planned_conv_matches_direct_oracle_at_awkward_lengths() {
    // End-to-end: the planned real-input convolution (auto-routed engine)
    // against the O(N²) direct oracles on small adversarial lengths.
    check(
        &cfg(48),
        "fft_conv ~ direct oracle (1e-9)",
        |r| {
            let n = r.range(1, 160);
            (r.vec(n, -1.0, 1.0), r.vec(n, -1.0, 1.0))
        },
        shrink_ab,
        |(u, k)| {
            let dl = max_abs_diff(&fft_conv_linear(u, k), &direct_conv_linear(u, k));
            if dl > 1e-9 {
                return Err(format!("linear diff {dl:e} at n={}", u.len()));
            }
            let dc = max_abs_diff(
                &ssm_rdu::fft::fft_conv_circular(u, k),
                &direct_conv_circular(u, k),
            );
            if dc > 1e-9 {
                return Err(format!("circular diff {dc:e} at n={}", u.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn split_radix_conv_agrees_with_naive_at_16k_watershed() {
    // L = 16384 ± 1 straddles SPLIT_RADIX_MIN_POINTS: 16383/16384 pad to a
    // 32768-point split-radix transform, while shorter lengths stay on
    // radix-2. Both sides of the watershed must agree with the unplanned
    // complex-FFT baseline (O(N log N), so this stays fast in debug builds).
    let mut rng = XorShift::new(cfg(1).seed);
    for l in [16383usize, 16384, 16385] {
        let u = rng.vec(l, -1.0, 1.0);
        let k = rng.vec(l, -1.0, 1.0);
        let d = max_abs_diff(&fft_conv_linear(&u, &k), &fft_conv_linear_naive(&u, &k));
        assert!(d < 1e-6, "L={l}: planned vs naive diff {d:e}");
    }
}

// ------------------------------------------------------- pooled / sharded

#[test]
fn prop_pooled_ragged_channels_bit_identical_to_serial() {
    // Ragged channel sets through the work-stealing pool: every channel
    // must be byte-equal to its own serial convolution regardless of the
    // claim order or thread count.
    check(
        &cfg(16),
        "pooled channels == serial per-channel",
        |r| {
            let ch = r.range(1, 6);
            let pairs: Vec<(Vec<f64>, Vec<f64>)> = (0..ch)
                .map(|_| {
                    let n = r.range(1, 300);
                    (r.vec(n, -1.0, 1.0), r.vec(n, -1.0, 1.0))
                })
                .collect();
            (pairs, r.range(1, 5))
        },
        no_shrink,
        |(pairs, threads)| {
            let us: Vec<Vec<f64>> = pairs.iter().map(|p| p.0.clone()).collect();
            let ks: Vec<Vec<f64>> = pairs.iter().map(|p| p.1.clone()).collect();
            let pool = WorkerPool::new(*threads);
            let got = fft_conv_linear_channels(&us, &ks, &pool);
            for (i, (u, k)) in us.iter().zip(&ks).enumerate() {
                if got[i] != fft_conv_linear(u, k) {
                    return Err(format!("channel {i} diverged under {threads} threads"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_scan_bit_identical_across_chip_counts() {
    // The sharded scan's per-chip arithmetic is shared between the serial
    // and pooled drivers, so any chip count must reproduce the single-chip
    // stream bit for bit — and the pooled fan-out must match the serial
    // sharded driver exactly.
    check(
        &cfg(32),
        "sharded scan == pooled sharded scan",
        |r| {
            let n = interesting_len(r).min(4096);
            (r.vec(n, -0.99, 0.99), r.vec(n, -1.0, 1.0), r.range(1, 6), r.range(1, 4))
        },
        no_shrink,
        |(a, b, chips, threads)| {
            let serial = sharded_mamba_scan(a, b, *chips);
            let pooled = sharded_mamba_scan_pooled(a, b, *chips, &WorkerPool::new(*threads));
            if serial != pooled {
                return Err(format!("pooled diverged at chips={chips}, threads={threads}"));
            }
            // Single-chip sharding degenerates to the serial recurrence.
            if *chips == 1 && serial != mamba_scan_serial(a, b) {
                return Err("chips=1 shard != serial recurrence".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_map_stealing_bit_identical_to_map() {
    check(
        &cfg(32),
        "map_stealing == map",
        |r| (r.range(0, 80), r.range(1, 9), r.next_u64()),
        no_shrink,
        |&(jobs, threads, salt)| {
            let pool = WorkerPool::new(threads);
            let f = |i: usize| (i as f64 + (salt % 1024) as f64).sqrt() * 3.0;
            let a: Vec<f64> = pool.map(jobs, f);
            let b: Vec<f64> = pool.map_stealing(jobs, f);
            if a == b {
                Ok(())
            } else {
                Err(format!("diverged at jobs={jobs}, threads={threads}"))
            }
        },
    );
}

#[test]
fn prop_resident_map_bit_identical_to_spawn_baseline() {
    // The resident-team facade must preserve the scoped-spawn baseline's
    // results exactly: same contiguous chunking, same index order, for any
    // (jobs, threads) — `map_spawn` is kept precisely to witness this.
    check(
        &cfg(24),
        "resident map == map_spawn",
        |r| (r.range(0, 80), r.range(1, 9), r.next_u64()),
        no_shrink,
        |&(jobs, threads, salt)| {
            let pool = WorkerPool::new(threads);
            let f = |i: usize| ((i * 7 + (salt % 513) as usize) as f64).sqrt().sin();
            let resident: Vec<f64> = pool.map(jobs, f);
            let spawned: Vec<f64> = pool.map_spawn(jobs, f);
            if resident == spawned {
                Ok(())
            } else {
                Err(format!("diverged at jobs={jobs}, threads={threads}"))
            }
        },
    );
}

// ------------------------------------------------------------- stealing

#[test]
fn prop_steal_queues_conserve_and_order_work() {
    // Single-threaded model check of the deque policy itself: under any
    // randomized push/claim/complete schedule, (a) nothing is lost or run
    // twice, (b) home claims come off the *front* of the home deque in
    // push order, and (c) outstanding accounting returns to zero.
    check(
        &cfg(64),
        "StealQueues conservation",
        |r| (r.range(1, 4), r.range(1, 40), r.next_u64()),
        no_shrink,
        |&(chips, items, seed)| {
            let mut rng = XorShift::new(seed);
            let mut q: StealQueues<(usize, usize)> = StealQueues::new(chips);
            let mut pushed = 0usize;
            let mut seen: Vec<(usize, usize)> = Vec::new();
            let mut last_home_seq = vec![0usize; chips];
            let mut inflight: Vec<usize> = Vec::new(); // origins
            let mut seq = 0usize;
            while pushed < items || !q.is_idle() {
                match rng.below(3) {
                    0 if pushed < items => {
                        let chip = rng.below(chips);
                        seq += 1;
                        q.push(chip, (chip, seq));
                        pushed += 1;
                    }
                    1 => {
                        let home = rng.below(chips);
                        if let Some(claim) = q.claim(home) {
                            let (origin, s) = claim.item;
                            if claim.origin != origin {
                                return Err("claim origin mislabeled".into());
                            }
                            if !claim.stolen {
                                // Home pops are FIFO per chip.
                                if s <= last_home_seq[origin] {
                                    return Err(format!("home pop out of order on chip {origin}"));
                                }
                                last_home_seq[origin] = s;
                            }
                            seen.push((origin, s));
                            inflight.push(claim.origin);
                        }
                    }
                    _ => {
                        if let Some(origin) = inflight.pop() {
                            q.complete(origin);
                        }
                    }
                }
            }
            while let Some(origin) = inflight.pop() {
                q.complete(origin);
            }
            if seen.len() != items {
                return Err(format!("{} of {items} items executed", seen.len()));
            }
            let mut uniq = seen.clone();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() != items {
                return Err("an item executed twice".into());
            }
            if q.total_outstanding() != 0 || q.total_queued() != 0 {
                return Err("queues did not drain to zero".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- pcusim

/// A generated pcusim case: lane count, interconnect mode, per-level op
/// rows (only routes `topology::allows` admits), and a random input batch.
type PcusimCase = (usize, PcuMode, Vec<Vec<Op>>, Vec<Vec<C64>>);

fn rand_c64(r: &mut XorShift) -> C64 {
    C64::new(r.uniform(-1.0, 1.0), r.uniform(-1.0, 1.0))
}

/// Draw a random stage chain the DSL must accept: every cross-lane source
/// is filtered through `topology::allows` against the same geometry the
/// builder validates with (stages = program depth).
fn gen_pcusim_case(r: &mut XorShift) -> PcusimCase {
    let lanes = *r.choose(&[2usize, 4, 8]);
    let mode = *r.choose(&[
        PcuMode::ElementWise,
        PcuMode::Reduction,
        PcuMode::Fft,
        PcuMode::HsScan,
        PcuMode::BScan,
    ]);
    let depth = r.range(1, 4);
    let geom = PcuGeometry::new(lanes, depth);
    let mut levels = Vec::with_capacity(depth);
    for li in 0..depth {
        let mut row = Vec::with_capacity(lanes);
        for dest in 0..lanes {
            let srcs: Vec<usize> = (0..lanes)
                .filter(|&s| s != dest && topology::allows(mode, geom, li, dest, s))
                .collect();
            let kind = r.below(6);
            let op = if kind >= 3 && srcs.is_empty() {
                ops::pass()
            } else {
                match kind {
                    0 => ops::pass(),
                    1 => ops::cnst(rand_c64(r)),
                    2 => ops::mul(rand_c64(r)),
                    3 => ops::add(*r.choose(&srcs)),
                    4 => ops::take(*r.choose(&srcs)),
                    _ => ops::mac(*r.choose(&srcs), rand_c64(r)),
                }
            };
            row.push(op);
        }
        levels.push(row);
    }
    let vectors = r.range(1, 6);
    let inputs =
        (0..vectors).map(|_| (0..lanes).map(|_| rand_c64(r)).collect()).collect();
    (lanes, mode, levels, inputs)
}

/// Straight-line scalar reference: apply each level's ops to the previous
/// level's outputs, per the `Op` semantics table in `pcusim::program`.
fn scalar_reference(levels: &[Vec<Op>], input: &[C64]) -> Vec<C64> {
    let mut cur = input.to_vec();
    for row in levels {
        let next: Vec<C64> = row
            .iter()
            .enumerate()
            .map(|(lane, op)| {
                let a = cur[lane];
                match *op {
                    Op::Pass => a,
                    Op::Const(c) => c,
                    Op::Add { src } => a + cur[src],
                    Op::Sub { src } => a - cur[src],
                    Op::MulConst(c) => a * c,
                    Op::Mac { src, c } => a + c * cur[src],
                    Op::MacSelf { src, c } => c * a + cur[src],
                    Op::TwiddleSub { src, c } => c * (cur[src] - a),
                    Op::Take { src } => cur[src],
                }
            })
            .collect();
        cur = next;
    }
    cur
}

#[test]
fn prop_pcusim_dsl_program_matches_scalar_reference() {
    check(
        &cfg(48),
        "pcusim DSL program == straight-line scalar reference",
        gen_pcusim_case,
        no_shrink,
        |(lanes, mode, levels, inputs)| {
            let mut b = ProgramBuilder::new("prop-prog", *mode, *lanes);
            for (li, row) in levels.iter().enumerate() {
                b.stage(format!("s{li}"), row.clone());
            }
            let prog =
                b.finish().map_err(|e| format!("builder rejected admitted routes: {e}"))?;
            let want: Vec<Vec<C64>> =
                inputs.iter().map(|v| scalar_reference(levels, v)).collect();
            let geom = PcuGeometry::new(*lanes, 12);
            // Extension fabric maps spatially; baseline serializes whenever
            // the mode is an extension. Both regimes must agree with the
            // reference exactly.
            for pcu in [Pcu::with_extension(geom, *mode), Pcu::baseline(geom)] {
                let (got, _) = pcu.run(&prog, inputs);
                if got != want {
                    return Err(format!(
                        "engine diverged from scalar reference ({lanes} lanes, {mode:?})"
                    ));
                }
            }
            // Routes were admitted at construction, so the matching fabric
            // must map the program spatially: vectors + stages - 1 cycles.
            let pcu = Pcu::with_extension(geom, *mode);
            let (_, stats) = pcu.run(&prog, inputs);
            if !stats.spatial {
                return Err("program with admitted routes must map spatially".into());
            }
            if stats.cycles != (inputs.len() + geom.stages - 1) as u64 {
                return Err(format!("spatial cycle count off: {}", stats.cycles));
            }
            // Single-stepping the debugger to completion reproduces the
            // batch engine bit for bit, stats included.
            let mut dbg = DebugSession::new(pcu, &prog, inputs.clone());
            while !dbg.is_done() {
                dbg.step();
            }
            if dbg.outputs() != &want[..] {
                return Err("debugger outputs diverged from reference".into());
            }
            if dbg.stats() != Some(stats) {
                return Err("debugger stats diverged from engine".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pcusim_builder_accepts_any_width_without_cross_routes() {
    // Straight-line (element-wise) programs carry no cross-lane routes, so
    // the builder must accept any width — including non-powers of two the
    // pow2-laned engine can never run. The level table is the contract.
    check(
        &cfg(32),
        "pcusim builder: straight-line programs at any width",
        |r| {
            let width = r.range(2, 9);
            let depth = r.range(1, 3);
            let levels: Vec<Vec<Op>> = (0..depth)
                .map(|_| {
                    (0..width)
                        .map(|_| match r.below(3) {
                            0 => ops::pass(),
                            1 => ops::cnst(rand_c64(r)),
                            _ => ops::mul(rand_c64(r)),
                        })
                        .collect()
                })
                .collect();
            let input: Vec<C64> = (0..width).map(|_| rand_c64(r)).collect();
            (width, levels, input)
        },
        no_shrink,
        |(width, levels, input)| {
            let mut b = ProgramBuilder::new("prop-ew", PcuMode::ElementWise, *width);
            for (li, row) in levels.iter().enumerate() {
                b.stage(format!("s{li}"), row.clone());
            }
            let prog = b.finish().map_err(|e| e.to_string())?;
            if prog.width() != *width {
                return Err(format!("width {} != {width}", prog.width()));
            }
            for (li, level) in prog.levels.iter().enumerate() {
                if level.ops != levels[li] {
                    return Err(format!("level {li} not preserved by the builder"));
                }
            }
            // The reference executor runs fine at odd widths even though
            // the engine's geometry cannot.
            let out = scalar_reference(levels, input);
            if out.len() != *width {
                return Err("reference output width mismatch".into());
            }
            Ok(())
        },
    );
}
