//! Hot-path compute-engine integration suite: the planned real-input FFT
//! convolution against the direct oracles (non-pow2 lengths included),
//! clean plan-mismatch panics, and bit-identity of every pooled execution
//! path against its serial counterpart — pooling and planning are
//! performance transforms and must never change the numerics.

use ssm_rdu::arch::RduConfig;
use ssm_rdu::coordinator::{Executor, ExecutorFactory, MockExecutor};
use ssm_rdu::fft::conv::{direct_conv_circular, direct_conv_linear};
use ssm_rdu::fft::{
    fft_conv_circular, fft_conv_circular_naive, fft_conv_linear, fft_conv_linear_channels,
    fft_conv_linear_naive, BaileyVariant, FftPlan, RealFftPlan,
};
use ssm_rdu::runtime::WorkerPool;
use ssm_rdu::session::driver::{simulate, simulate_pooled, SimConfig};
use ssm_rdu::shard::{
    sharded_bailey_fft, sharded_bailey_fft_pooled, sharded_mamba_scan, sharded_mamba_scan_pooled,
};
use ssm_rdu::util::{max_abs_diff, C64, XorShift};
use ssm_rdu::workloads::hyena_conv_channels;

#[test]
fn planned_conv_matches_direct_oracles_at_non_pow2_lengths() {
    // The acceptance bound: every fast-path output within 1e-9 of the
    // O(N²) direct oracles, across awkward (non-power-of-two) lengths.
    let mut rng = XorShift::new(301);
    for n in [1usize, 2, 3, 7, 100, 129, 1000, 1023, 4097] {
        let u = rng.vec(n, -1.0, 1.0);
        let k = rng.vec(n, -1.0, 1.0);
        let d = max_abs_diff(&fft_conv_linear(&u, &k), &direct_conv_linear(&u, &k));
        assert!(d < 1e-9, "linear n={n}: diff={d}");
        if n.is_power_of_two() {
            let d = max_abs_diff(&fft_conv_circular(&u, &k), &direct_conv_circular(&u, &k));
            assert!(d < 1e-9, "circular n={n}: diff={d}");
        }
    }
}

#[test]
fn planned_conv_matches_the_pre_plan_naive_path() {
    let mut rng = XorShift::new(302);
    for l in [1usize << 10, 1 << 12] {
        let u = rng.vec(l, -1.0, 1.0);
        let k = rng.vec(l, -1.0, 1.0);
        let d = max_abs_diff(&fft_conv_circular(&u, &k), &fft_conv_circular_naive(&u, &k));
        assert!(d < 1e-9, "circular L={l}: diff={d}");
        let d = max_abs_diff(&fft_conv_linear(&u, &k), &fft_conv_linear_naive(&u, &k));
        assert!(d < 1e-9, "linear L={l}: diff={d}");
    }
}

#[test]
#[should_panic(expected = "FftPlan for N=4096")]
fn fft_plan_reuse_across_mismatched_lengths_panics_cleanly() {
    let plan = FftPlan::new(4096);
    let mut wrong = vec![C64::ZERO; 1024];
    plan.fft_in_place(&mut wrong); // 1k buffer into a 4k plan: loud, named panic
}

#[test]
#[should_panic(expected = "RealFftPlan for N=2048")]
fn real_plan_reuse_across_mismatched_lengths_panics_cleanly() {
    let mut plan = RealFftPlan::new(2048);
    let mut spec = vec![C64::ZERO; plan.spectrum_len()];
    plan.rfft_into(&[0.0; 4096], &mut spec);
}

#[test]
fn pooled_hyena_channels_bit_identical_to_serial() {
    // The satellite contract: pooled Hyena conv for L ∈ {1k, 4k} is
    // bit-identical to the serial per-channel loop, at several pool widths.
    let mut rng = XorShift::new(303);
    let d = 16;
    for l in [1usize << 10, 1 << 12] {
        let us: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let ks: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let serial: Vec<Vec<f64>> =
            us.iter().zip(&ks).map(|(u, k)| fft_conv_linear(u, k)).collect();
        for threads in [1usize, 2, 5, 16] {
            let pool = WorkerPool::new(threads);
            let pooled = fft_conv_linear_channels(&us, &ks, &pool);
            assert_eq!(pooled, serial, "L={l} threads={threads}");
            assert_eq!(hyena_conv_channels(&us, &ks, &pool), serial, "workloads wrapper");
        }
        // And the channels themselves are oracle-exact.
        for (u, k) in us.iter().zip(&ks).take(2) {
            let d = max_abs_diff(&fft_conv_linear(u, k), &direct_conv_linear(u, k));
            assert!(d < 1e-9, "L={l}: diff={d}");
        }
    }
}

#[test]
fn pooled_sharded_mamba_scan_two_chips_bit_identical() {
    let mut rng = XorShift::new(304);
    for n in [100usize, 1000, 1 << 12] {
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b = rng.vec(n, -1.0, 1.0);
        let serial = sharded_mamba_scan(&a, &b, 2);
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            assert_eq!(
                sharded_mamba_scan_pooled(&a, &b, 2, &pool),
                serial,
                "n={n} threads={threads}: --chips 2 pooled must be bit-exact"
            );
        }
    }
}

#[test]
fn pooled_sharded_bailey_fft_bit_identical() {
    let mut rng = XorShift::new(305);
    let x: Vec<C64> = (0..4096)
        .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
        .collect();
    let pool = WorkerPool::new(3);
    for chips in [2usize, 4] {
        for variant in [BaileyVariant::Vector, BaileyVariant::Gemm] {
            assert_eq!(
                sharded_bailey_fft_pooled(&x, 32, chips, variant, &pool),
                sharded_bailey_fft(&x, 32, chips, variant),
                "chips={chips} {variant:?}"
            );
        }
    }
}

#[test]
fn pooled_session_sim_matches_serial_end_to_end() {
    let cfg = SimConfig::demo(12, 5);
    let d_model = cfg.mamba_shape.d_model;
    let rdu = RduConfig::hs_scan_mode();
    let serial = {
        let mut exec = MockExecutor::new(1, d_model);
        simulate(&mut exec, &cfg, &rdu).unwrap()
    };
    let factory: ExecutorFactory =
        Box::new(move || Ok(Box::new(MockExecutor::new(1, d_model)) as Box<dyn Executor>));
    let pooled = simulate_pooled(&factory, &cfg, &rdu, 3).unwrap();
    assert_eq!(pooled.tokens, serial.tokens);
    assert_eq!(pooled.sched.retired, serial.sched.retired);
    assert_eq!(pooled.batches, serial.batches);
    assert_eq!(pooled.sim_seconds, serial.sim_seconds, "full budget: modeled time identical");
}
