//! Deterministic interleaving stress test for the work-stealing dispatch
//! design (ARCHITECTURE.md §5.4): the exact policy objects the coordinator
//! composes — [`StealQueues`] (per-chip deques + outstanding accounting),
//! [`SessionScheduler`] (one step per session in flight) and the per-chip
//! [`StateCache`]s (byte-budgeted, spill-on-overflow) — are driven
//! *single-threaded* through randomized arrival / claim / steal /
//! completion schedules, so every interleaving the threaded coordinator
//! could produce is replayed deterministically and the invariants are
//! checked after **every** event:
//!
//! * no session step is lost or executed twice, and each session's steps
//!   execute in strict step order (the scheduler's in-flight rule);
//! * a chip's resident state never exceeds its byte budget, even while
//!   steps of other sessions interleave with spill/restore traffic;
//! * steal accounting conserves work: every claim is completed against its
//!   *origin* chip and the deques drain to zero.
//!
//! 96 seeds × randomized schedules. Failures print the seed; replay by
//! filtering the schedule loop to it.

use ssm_rdu::runtime::{ModelKind, StealQueues};
use ssm_rdu::session::{
    Phase, ScheduledStep, SchedulerConfig, SessionId, SessionInfo, SessionScheduler, SsmState,
    StateCache, StateShape, StepOutcome,
};
use ssm_rdu::util::XorShift;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One session step travelling through the deques (the coordinator's
/// `StepTask`, minus the I/O plumbing).
#[derive(Debug, Clone, Copy)]
struct Step {
    session: SessionId,
    phase: Phase,
    step: usize,
    chip: usize,
}

/// Per-seed scenario outcome folded into the cross-seed assertions.
#[derive(Default)]
struct Outcome {
    steals: u64,
    evictions: u64,
    executed: usize,
}

fn chip_of(id: SessionId, chips: usize) -> usize {
    (id % chips as u64) as usize
}

/// Drive one fully randomized schedule to completion and check every
/// invariant along the way.
fn run_schedule(seed: u64) -> Outcome {
    let mut rng = XorShift::new(seed);
    let chips = rng.range(1, 4);
    let n_sessions = rng.range(2, 12) as u64;
    let shape = StateShape::mamba(1, 4, 8); // 128 B per session state
    let state_bytes = shape.bytes();
    // Tight budget: 1–2 resident states per chip, so decode traffic spills.
    let budget = state_bytes * rng.range(1, 2);

    let mut sched = SessionScheduler::new(SchedulerConfig {
        max_batch: rng.range(1, 4),
        session_timeout: Duration::from_secs(600),
    });
    let mut caches: Vec<StateCache> =
        (0..chips).map(|_| StateCache::with_budget_bytes(budget)).collect();
    let mut queues: StealQueues<Step> = StealQueues::new(chips);

    // Sessions to admit, their decode lengths, and progress bookkeeping.
    let decode_steps: BTreeMap<SessionId, usize> =
        (0..n_sessions).map(|id| (id, rng.range(2, 7))).collect();
    let mut to_admit: Vec<SessionId> = (0..n_sessions).collect();
    let mut next_expected: BTreeMap<SessionId, usize> = BTreeMap::new();
    let mut executed: Vec<(SessionId, usize)> = Vec::new();
    // Steps executed but whose feedback has not reached the scheduler yet —
    // the randomized analogue of Msg::Feedback sitting in the channel.
    let mut pending_feedback: Vec<(SessionId, usize)> = Vec::new();
    let mut out = Outcome::default();

    let total_steps: usize = decode_steps.values().sum();
    let mut guard = 0usize;
    loop {
        guard += 1;
        assert!(guard < 100_000, "seed {seed}: schedule failed to converge");
        let done = to_admit.is_empty()
            && sched.is_idle()
            && pending_feedback.is_empty()
            && queues.is_idle();
        if done {
            break;
        }
        match rng.below(4) {
            // Arrival: admit a waiting session at a random point.
            0 if !to_admit.is_empty() => {
                let id = to_admit.remove(rng.below(to_admit.len()));
                sched.admit(
                    id,
                    SessionInfo {
                        model: ModelKind::Mamba,
                        shape,
                        decode_steps: decode_steps[&id],
                    },
                    Instant::now(),
                );
                next_expected.insert(id, 0);
            }
            // Dispatch: push every ready step onto its home chip's deque
            // (the continuous loop's wave cut — no iteration barrier).
            1 => {
                for s in sched.next_batch() {
                    let ScheduledStep { id, phase, step, .. } = s;
                    let chip = chip_of(id, chips);
                    queues.push(chip, Step { session: id, phase, step, chip });
                }
            }
            // Execute: a random worker (random home chip) claims home-first
            // then steals, runs the step against the origin chip's cache,
            // and completes against the origin.
            2 => {
                let home = rng.below(chips);
                if let Some(claim) = queues.claim(home) {
                    if claim.stolen {
                        out.steals += 1;
                        assert_ne!(
                            claim.origin, home,
                            "seed {seed}: steal reported from the worker's own chip"
                        );
                    }
                    let t = claim.item;
                    assert_eq!(t.chip, claim.origin, "seed {seed}: claim origin mislabeled");
                    // Ordering: exactly the next step this session expects.
                    let want = next_expected[&t.session];
                    assert_eq!(
                        t.step, want,
                        "seed {seed}: session {} ran step {} before step {want}",
                        t.session, t.step
                    );
                    let cache = &mut caches[t.chip];
                    match t.phase {
                        Phase::Prefill => {
                            assert_eq!(t.step, 0, "seed {seed}: prefill must be step 0");
                            cache.insert(t.session, SsmState::zeros(&shape).unwrap());
                        }
                        Phase::Decode => {
                            let mut st = cache
                                .checkout(t.session)
                                .unwrap_or_else(|| panic!("seed {seed}: state lost"));
                            // The state counts decode steps: spill/restore
                            // must preserve it exactly.
                            let got = st.mean();
                            let want_mean = (t.step - 1) as f32;
                            assert_eq!(
                                got, want_mean,
                                "seed {seed}: session {} state corrupted", t.session
                            );
                            st.add_scalar(1.0);
                            cache.checkin(t.session, st);
                        }
                    }
                    executed.push((t.session, t.step));
                    *next_expected.get_mut(&t.session).unwrap() += 1;
                    queues.complete(claim.origin);
                    pending_feedback.push((t.session, t.step));
                }
            }
            // Feedback: deliver a random executed step's completion to the
            // scheduler (retiring the session after its last token).
            _ => {
                if !pending_feedback.is_empty() {
                    let (id, _step) =
                        pending_feedback.remove(rng.below(pending_feedback.len()));
                    let outcome = sched.on_step_done(id, Instant::now());
                    if outcome == StepOutcome::Retired {
                        let st = caches[chip_of(id, chips)].remove(id);
                        assert!(st.is_some(), "seed {seed}: retired session had no state");
                    }
                }
            }
        }
        // Byte-budget invariant after *every* event, on every chip.
        for (c, cache) in caches.iter().enumerate() {
            assert!(
                cache.resident_bytes() <= cache.budget_bytes(),
                "seed {seed}: chip {c} resident {} bytes over budget {}",
                cache.resident_bytes(),
                cache.budget_bytes()
            );
        }
    }

    // Conservation: every step of every session executed exactly once, in
    // order (checked inline above), and nothing else ran.
    assert_eq!(executed.len(), total_steps, "seed {seed}: lost or duplicated steps");
    let mut uniq = executed.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), total_steps, "seed {seed}: a step executed twice");
    for (id, &n) in &decode_steps {
        assert_eq!(
            next_expected[id], n,
            "seed {seed}: session {id} ran {} of {n} steps",
            next_expected[id]
        );
    }
    // All state retired with its session; the deques drained.
    for (c, cache) in caches.iter().enumerate() {
        assert_eq!(
            cache.resident_len() + cache.spilled_len(),
            0,
            "seed {seed}: chip {c} leaked state"
        );
        out.evictions += cache.stats.evictions;
    }
    assert_eq!(queues.total_queued(), 0, "seed {seed}");
    assert_eq!(queues.total_outstanding(), 0, "seed {seed}");
    assert_eq!(sched.stats.retired, n_sessions, "seed {seed}: not every session retired");
    out.executed = executed.len();
    out
}

#[test]
fn randomized_interleavings_preserve_order_budget_and_conservation() {
    // ≥64 distinct schedules (96 here): arrival order, wave cuts, claim /
    // steal order, and feedback delivery order are all randomized per seed.
    let mut steals = 0u64;
    let mut evictions = 0u64;
    let mut executed = 0usize;
    for seed in 1..=96u64 {
        let o = run_schedule(seed);
        steals += o.steals;
        evictions += o.evictions;
        executed += o.executed;
    }
    // The sweep must actually exercise the interesting regimes, or the
    // invariants above prove nothing.
    assert!(executed > 1000, "sweep too small: {executed} steps");
    assert!(steals > 0, "no schedule ever stole — stealing path unexercised");
    assert!(evictions > 0, "no schedule ever spilled — budget path unexercised");
}

#[test]
fn interleavings_are_deterministic_per_seed() {
    // The whole point of the harness: a seed fully determines the schedule,
    // so any failure above reproduces exactly.
    for seed in [3u64, 17, 64] {
        let a = run_schedule(seed);
        let b = run_schedule(seed);
        assert_eq!(a.steals, b.steals, "seed {seed}");
        assert_eq!(a.evictions, b.evictions, "seed {seed}");
        assert_eq!(a.executed, b.executed, "seed {seed}");
    }
}
