//! Workload-registry integration suite (ISSUE 5 acceptance):
//!
//! * SSD chunked scan **bit-identical** to the naive `scan::recurrence`
//!   path, for ragged lengths and through the 2-chip sharded driver;
//! * S4 conv matches the naive complex-FFT path ≤ 1e-9 on non-pow2 lengths;
//! * registry round-trip: every registered workload builds, maps, fuses and
//!   estimates without panicking, and the sweep/shard/decode layers resolve
//!   it uniformly.

use ssm_rdu::arch::{InterchipLink, RduConfig};
use ssm_rdu::dfmodel;
use ssm_rdu::runtime::WorkerPool;
use ssm_rdu::scan::mamba_scan_serial;
use ssm_rdu::shard::{self, sharded_ssd_scan};
use ssm_rdu::util::{max_abs_diff, XorShift};
use ssm_rdu::workloads::{
    lookup, registry, registry_names, s4_conv, s4_conv_channels, s4_kernel, ssd_scan,
    ssd_scan_semiseparable, ssm_workloads, DecoderConfig, ShardComm,
};

// ---------------------------------------------------------------- SSD

#[test]
fn ssd_chunked_scan_bit_identical_for_ragged_lengths() {
    let mut rng = XorShift::new(501);
    for n in [1usize, 13, 100, 255, 256, 257, 1000, 1023, 4096] {
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b = rng.vec(n, -1.0, 1.0);
        let want = mamba_scan_serial(&a, &b);
        for q in [1usize, 32, 256, 1 << 14] {
            assert_eq!(ssd_scan(&a, &b, q), want, "n={n} q={q}: SSD must not change a bit");
        }
    }
}

#[test]
fn ssd_chunked_scan_bit_identical_at_two_chips() {
    // The acceptance point: ragged L, --chips 2, exact equality — the
    // per-chip chunked scans chained through the carry exchange reproduce
    // the serial recurrence bitwise.
    let mut rng = XorShift::new(502);
    for n in [2usize, 101, 1000, 1023] {
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b = rng.vec(n, -1.0, 1.0);
        let want = mamba_scan_serial(&a, &b);
        assert_eq!(sharded_ssd_scan(&a, &b, 2, 256), want, "n={n} chips=2");
        for chips in [3usize, 5, 8] {
            assert_eq!(sharded_ssd_scan(&a, &b, chips, 64), want, "n={n} chips={chips}");
        }
    }
}

#[test]
fn ssd_semiseparable_evaluation_within_budget() {
    // The matmul-order evaluation (what the graph prices on the systolic
    // arrays) regroups floating point; it must stay inside the 1e-9 budget.
    let mut rng = XorShift::new(503);
    let n = 777;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let b = rng.vec(n, -1.0, 1.0);
    let want = mamba_scan_serial(&a, &b);
    for q in [8usize, 64, 256] {
        let d = max_abs_diff(&ssd_scan_semiseparable(&a, &b, q), &want);
        assert!(d < 1e-9, "q={q}: |d|={d}");
    }
}

// ---------------------------------------------------------------- S4

#[test]
fn s4_conv_matches_naive_fft_path_on_non_pow2_lengths() {
    let mut rng = XorShift::new(504);
    for l in [100usize, 777, 1000, 3000] {
        let u = rng.vec(l, -1.0, 1.0);
        let lambda: Vec<f64> = (0..4).map(|_| rng.uniform(0.5, 0.99)).collect();
        let c = rng.vec(4, -1.0, 1.0);
        let k = s4_kernel(&lambda, &c, l);
        let planned = s4_conv(&u, &lambda, &c);
        let naive = ssm_rdu::fft::fft_conv_linear_naive(&u, &k);
        let d = max_abs_diff(&planned, &naive);
        assert!(d < 1e-9, "L={l}: planned vs naive |d|={d}");
        // And against the O(L²) oracle on the smaller lengths.
        if l <= 1000 {
            let direct = ssm_rdu::fft::conv::direct_conv_linear(&u, &k);
            let d2 = max_abs_diff(&planned, &direct);
            assert!(d2 < 1e-9, "L={l}: planned vs direct |d|={d2}");
        }
    }
}

#[test]
fn s4_pooled_channels_bit_identical_to_serial() {
    let mut rng = XorShift::new(505);
    let ch = 6;
    let l = 1000;
    let us: Vec<Vec<f64>> = (0..ch).map(|_| rng.vec(l, -1.0, 1.0)).collect();
    let lambdas: Vec<Vec<f64>> =
        (0..ch).map(|_| (0..4).map(|_| rng.uniform(0.5, 0.99)).collect()).collect();
    let cs: Vec<Vec<f64>> = (0..ch).map(|_| rng.vec(4, -1.0, 1.0)).collect();
    let serial: Vec<Vec<f64>> = (0..ch).map(|i| s4_conv(&us[i], &lambdas[i], &cs[i])).collect();
    for threads in [1usize, 2, 4] {
        assert_eq!(
            s4_conv_channels(&us, &lambdas, &cs, &WorkerPool::new(threads)),
            serial,
            "threads={threads}"
        );
    }
}

// ------------------------------------------------------------ registry

#[test]
fn registry_roundtrip_builds_maps_fuses_estimates() {
    // Every registered workload, resolved by name, must flow through the
    // whole modeling stack without panicking.
    let dc = DecoderConfig::paper(1 << 14);
    for name in registry_names() {
        let w = lookup(name).unwrap_or_else(|| panic!("{name} must resolve"));
        let g = w.build_graph(&dc);
        assert!(g.validate().is_ok(), "{name}: {:?}", g.validate());

        let cfg = w.extended_config();
        let mapping = dfmodel::map_graph(&g, &cfg).unwrap_or_else(|e| panic!("{name}: map {e}"));
        assert!(mapping.max_pcus_used() <= cfg.spec.n_pcu, "{name}");

        let plan = dfmodel::fuse_graph(&g, &cfg);
        let mut seen = vec![false; g.kernels.len()];
        for cluster in &plan.clusters {
            for &k in cluster {
                assert!(!seen[k], "{name}: kernel {k} fused twice");
                seen[k] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "{name}: fusion must cover every kernel");

        let ideal = dfmodel::estimate(&g, &cfg).unwrap();
        let fused = dfmodel::estimate_fused(&g, &cfg).unwrap();
        let unfused = dfmodel::estimate_unfused(&g, &cfg).unwrap();
        assert!(ideal.total_seconds > 0.0 && ideal.total_seconds.is_finite(), "{name}");
        assert!(fused.total_seconds <= unfused.total_seconds, "{name}: fusion never loses");
        assert!(fused.sections <= unfused.sections, "{name}");

        let cost = dfmodel::decode_step_workload(w, &dc, 8, &cfg);
        assert!(cost.seconds > 0.0 && cost.flops > 0.0, "{name}");
    }
}

#[test]
fn fused_strictly_beats_unfused_for_the_new_workloads() {
    // The existing gate covers hyena/mamba; pin the same strict win for SSD
    // and S4 at the L = 4K acceptance point and a production length.
    for l in [1usize << 12, 1 << 16] {
        let dc = DecoderConfig::paper(l);
        for name in ["ssd", "s4"] {
            let w = lookup(name).unwrap();
            let g = w.build_graph(&dc);
            let cfg = w.extended_config();
            let f = dfmodel::estimate_fused(&g, &cfg).unwrap();
            let u = dfmodel::estimate_unfused(&g, &cfg).unwrap();
            assert!(
                f.total_seconds < u.total_seconds,
                "{name} @ L={l}: fused {} !< unfused {}",
                f.total_seconds,
                u.total_seconds
            );
            assert!(f.sections < u.sections, "{name} @ L={l}: fusion must reduce launches");
        }
    }
}

#[test]
fn every_ssm_workload_sweeps_and_shards() {
    let dc = DecoderConfig::paper(1 << 16);
    let wls = ssm_workloads();
    // One sweep point over all SSM workloads: rows present and finite.
    let pts = dfmodel::sweep_pcu_count(&dc, &[520], &wls);
    assert_eq!(pts.len(), 1);
    assert_eq!(pts[0].rows.len(), wls.len());
    for r in &pts[0].rows {
        assert!(r.seconds.is_finite() && r.seconds > 0.0, "{r:?}");
        assert!(r.gain >= 1.0 - 1e-9, "{r:?}");
    }
    // Sharded estimates resolve for every shardable workload at 2 chips.
    let link = InterchipLink::rdu_fabric();
    for w in &wls {
        assert_ne!(w.shard_comm(&dc), ShardComm::Unsupported, "{} is shardable", w.name());
        let s = shard::sharded_estimate_workload(*w, &dc, 2, &w.extended_config(), &link)
            .unwrap_or_else(|e| panic!("{}: {e}", w.name()));
        assert_eq!(s.workload, w.name());
        assert!(s.comm_seconds > 0.0, "{}", w.name());
    }
}

#[test]
fn golden_checks_hold_through_the_registry() {
    for w in ssm_workloads() {
        let gc = w.golden_check(99).expect("SSM workloads carry a golden model");
        let label = format!("{} vs {}", w.name(), gc.reference);
        assert!(gc.max_abs_diff < 1e-9, "{label}: |d|={}", gc.max_abs_diff);
        if gc.bit_identical {
            assert_eq!(gc.max_abs_diff, 0.0, "{}", w.name());
        }
    }
}

#[test]
fn ssd_design_point_needs_no_extension() {
    // The SSD architectural claim, end to end: its estimate on the baseline
    // RDU equals its estimate on the scan-extended RDU (no ScanParallel
    // kernels to accelerate), and both beat the C-scan Mamba design.
    let dc = DecoderConfig::paper(1 << 18);
    let ssd = lookup("ssd").unwrap().build_graph(&dc);
    let on_base = dfmodel::estimate(&ssd, &RduConfig::baseline()).unwrap().total_seconds;
    let on_scan = dfmodel::estimate(&ssd, &RduConfig::hs_scan_mode()).unwrap().total_seconds;
    assert!((on_base - on_scan).abs() / on_base < 1e-9, "base={on_base} scan={on_scan}");
    let cscan = ssm_rdu::workloads::mamba_decoder(&dc, ssm_rdu::workloads::ScanVariant::CScan);
    let cscan_s = dfmodel::estimate(&cscan, &RduConfig::baseline()).unwrap().total_seconds;
    assert!(on_base < cscan_s, "chunking must beat the serial C-scan: {on_base} vs {cscan_s}");
}

#[test]
fn registry_covers_exactly_the_documented_names() {
    assert_eq!(registry().len(), 5);
    assert_eq!(registry_names(), vec!["attention", "hyena", "mamba", "ssd", "s4"]);
}
