//! Coordinator integration: routing, batching, padding, failure injection
//! and metrics under the mock executor (deterministic), plus one full
//! PJRT-backed serving pass when artifacts are present.

use ssm_rdu::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Executor, ExecutorFactory, MockExecutor,
    PjrtExecutor,
};
use ssm_rdu::runtime::ModelKind;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn mock_factory(slots: usize, elems: usize, delay_ms: u64) -> ExecutorFactory {
    Box::new(move || {
        let mut m = MockExecutor::new(slots, elems);
        m.delay = Duration::from_millis(delay_ms);
        Ok(Box::new(m) as Box<dyn Executor>)
    })
}

#[test]
fn responses_match_requests_under_mixed_load() {
    let c = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            workers: 2,
            ..Default::default()
        },
        mock_factory(4, 16, 0),
    )
    .unwrap();
    // Tag each request with a unique value; mock adds 1.0.
    let rxs: Vec<_> = (0..64)
        .map(|i| {
            let model = ModelKind::ALL[i % 3];
            let rx = c.submit(model, vec![i as f32; 16]).unwrap();
            (i, model, rx)
        })
        .collect();
    for (i, model, rx) in rxs {
        let r = rx.recv().expect("response");
        assert_eq!(r.model, model);
        assert_eq!(r.output, vec![i as f32 + 1.0; 16], "request {i}");
    }
    assert_eq!(c.metrics.responses.load(Ordering::Relaxed), 64);
    c.shutdown();
}

#[test]
fn deadline_flush_bounds_latency() {
    // A single request must not wait for a full batch.
    let c = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(10) },
            workers: 1,
            ..Default::default()
        },
        mock_factory(64, 4, 0),
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    let r = c.call(ModelKind::Hyena, vec![0.0; 4]).unwrap();
    assert!(t0.elapsed() < Duration::from_millis(500));
    assert_eq!(r.batch_size, 1);
    c.shutdown();
}

#[test]
fn poisoned_batches_fail_without_hanging_others() {
    let factory: ExecutorFactory = Box::new(|| {
        let mut m = MockExecutor::new(2, 2);
        m.poison = Some(-13.0);
        Ok(Box::new(m) as Box<dyn Executor>)
    });
    let c = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            workers: 1,
            ..Default::default()
        },
        factory,
    )
    .unwrap();
    let bad = c.submit(ModelKind::Mamba, vec![-13.0, 0.0]).unwrap();
    let good = c.submit(ModelKind::Mamba, vec![1.0, 1.0]).unwrap();
    assert!(bad.recv().is_err(), "poisoned request fails");
    assert_eq!(good.recv().unwrap().output, vec![2.0, 2.0]);
    assert_eq!(c.metrics.failures.load(Ordering::Relaxed), 1);
    c.shutdown();
}

#[test]
fn worker_construction_failure_surfaces_at_start() {
    let factory: ExecutorFactory = Box::new(|| anyhow::bail!("no backend"));
    let r = Coordinator::start(CoordinatorConfig::default(), factory);
    assert!(r.is_err());
}

#[test]
fn throughput_scales_with_workers() {
    let run = |workers: usize| {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
                workers,
                ..Default::default()
            },
            mock_factory(1, 4, 5),
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> =
            (0..12).map(|_| c.submit(ModelKind::Attention, vec![0.0; 4]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed();
        c.shutdown();
        dt
    };
    let one = run(1);
    let four = run(4);
    assert!(four < one, "4 workers {four:?} should beat 1 worker {one:?}");
}

#[test]
fn metrics_track_batching() {
    let c = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) },
            workers: 1,
            ..Default::default()
        },
        mock_factory(4, 4, 1),
    )
    .unwrap();
    let rxs: Vec<_> =
        (0..8).map(|_| c.submit(ModelKind::Hyena, vec![0.0; 4]).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let mean = c.metrics.mean_batch_size();
    assert!(mean > 1.0, "bursty load should batch: mean={mean}");
    assert!(c.metrics.latency_quantile_us(0.5) > 0);
    c.shutdown();
}

/// Full PJRT-backed serving pass (skips when artifacts are absent).
#[test]
fn pjrt_serving_end_to_end() {
    let dir = ssm_rdu::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let manifest = ssm_rdu::runtime::Manifest::load(dir.join("manifest.json")).unwrap();
    let elems = manifest.seq_len * manifest.d_model;
    let dir2 = dir.clone();
    let c = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy {
                max_batch: manifest.batch,
                max_wait: Duration::from_millis(5),
            },
            workers: 1,
            ..Default::default()
        },
        Box::new(move || {
            // Mamba only: cheapest artifact, keeps the test fast.
            let rt = ssm_rdu::runtime::Runtime::load_subset(&dir2, &[ModelKind::Mamba])?;
            Ok(Box::new(PjrtExecutor::new(rt)) as Box<dyn Executor>)
        }),
    )
    .unwrap();
    let rxs: Vec<_> = (0..6)
        .map(|i| c.submit(ModelKind::Mamba, vec![0.01 * i as f32; elems]).unwrap())
        .collect();
    for rx in rxs {
        let r = rx.recv().expect("pjrt response");
        assert_eq!(r.output.len(), elems);
        assert!(r.output.iter().all(|v| v.is_finite()));
    }
    c.shutdown();
}

#[test]
fn backpressure_sheds_load() {
    // A slow backend with a tiny in-flight cap: submits beyond the cap
    // fail fast instead of queueing unboundedly.
    let c = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            workers: 1,
            max_inflight: 4,
            ..Default::default()
        },
        mock_factory(1, 2, 50),
    )
    .unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..16 {
        match c.submit(ModelKind::Mamba, vec![0.0; 2]) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "cap of 4 with 16 instant submits must reject some");
    assert!(accepted.len() >= 4, "the cap's worth must be accepted");
    for rx in accepted {
        rx.recv().unwrap();
    }
    assert_eq!(c.inflight(), 0, "drained");
    c.shutdown();
}

#[test]
fn continuous_serving_64_sessions_under_pressure() {
    // The acceptance scenario of the session subsystem: ≥ 64 concurrent
    // sessions decode to completion under a cache budget smaller than the
    // total state footprint — evictions happen, numerics are unaffected,
    // per-token latency lands in the metrics.
    use ssm_rdu::coordinator::ContinuousConfig;
    use ssm_rdu::session::{SchedulerConfig, StateShape};

    let sessions = 64usize;
    let steps = 4usize;
    let mamba = StateShape::mamba(4, 8, 16); // 2 KiB per session
    let hyena = StateShape::hyena(4, 16, 32); // 2 KiB per session
    let footprint = (sessions / 2) * (mamba.bytes() + hyena.bytes());
    let budget = footprint / 4; // far smaller than the footprint
    let cc = ContinuousConfig {
        sched: SchedulerConfig { max_batch: 16, session_timeout: Duration::from_secs(10) },
        budget_bytes: budget,
        mamba_shape: mamba,
        hyena_shape: hyena,
        chips: 1,
    };
    let c = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            max_inflight: sessions * 2,
            continuous: Some(cc),
            ..Default::default()
        },
        mock_factory(1, 16, 0),
    )
    .unwrap();

    let rxs: Vec<_> = (0..sessions)
        .map(|i| {
            let model = if i % 2 == 0 { ModelKind::Mamba } else { ModelKind::Hyena };
            c.submit_session(model, vec![0.01 * (i as f32 + 1.0); 16], steps).unwrap()
        })
        .collect();
    let mut tokens = 0u64;
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut got = 0usize;
        while let Ok(r) = rx.recv() {
            assert_eq!(r.token_index, Some(got), "session {i} streams in order");
            assert_eq!(r.output.len(), 16);
            got += 1;
            tokens += 1;
        }
        assert_eq!(got, steps, "session {i} decoded to completion");
    }
    assert_eq!(tokens, (sessions * steps) as u64);
    assert_eq!(c.metrics.tokens.load(Ordering::Relaxed), tokens);
    assert_eq!(c.metrics.failures.load(Ordering::Relaxed), 0);
    assert_eq!(c.inflight(), 0, "every session retired");

    let cs = c.cache_stats().expect("continuous mode");
    assert!(cs.evictions > 0, "budget {budget} < footprint {footprint} must evict: {cs:?}");
    assert!(cs.restores > 0, "evicted sessions decoded again, so spills restored");
    assert!(cs.peak_resident_bytes as usize <= budget, "resident bytes bounded by budget");
    assert!(c.metrics.token_quantile_us(0.95) > 0, "per-token latency recorded");

    let ss = c.scheduler_stats().expect("continuous mode");
    assert_eq!(ss.admitted, sessions as u64);
    assert_eq!(ss.retired, sessions as u64);
    assert_eq!(ss.prefill_steps, sessions as u64);
    assert_eq!(ss.decode_steps, (sessions * (steps - 1)) as u64);
    assert!(
        c.metrics.mean_batch_size() > 1.0,
        "iteration batches form under 64-way concurrency: mean={}",
        c.metrics.mean_batch_size()
    );
    c.shutdown();
}
