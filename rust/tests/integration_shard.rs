//! Integration tests for the multi-chip sharding subsystem: the sharded
//! dataflows against their single-chip golden references across chip counts
//! and ragged lengths, the strong-scaling model, and the sharded
//! continuous-serving path end-to-end over the MockExecutor.

use ssm_rdu::arch::{InterchipLink, RduConfig};
use ssm_rdu::coordinator::{
    ContinuousConfig, Coordinator, CoordinatorConfig, Executor, MockExecutor,
};
use ssm_rdu::fft::{dft, BaileyVariant};
use ssm_rdu::runtime::ModelKind;
use ssm_rdu::scan::{c_scan_inclusive, mamba_scan_serial};
use ssm_rdu::session::StateShape;
use ssm_rdu::shard::{
    sharded_bailey_fft, sharded_mamba_scan, shard_ranges, strong_scaling,
};
use ssm_rdu::util::complex::max_abs_diff_c;
use ssm_rdu::util::{max_abs_diff, C64, XorShift};
use ssm_rdu::workloads::DecoderConfig;

const CHIP_COUNTS: [usize; 4] = [1, 2, 4, 8];

#[test]
fn sharded_scan_matches_serial_reference_everywhere() {
    // Chip counts {1, 2, 4, 8} × lengths with non-power-of-two remainders:
    // 1000 = 8×125, 1003 leaves ragged tails, 7 < 8 leaves empty chips.
    let mut rng = XorShift::new(101);
    for &n in &[1usize, 7, 64, 1000, 1003, 4096] {
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let want = mamba_scan_serial(&a, &b);
        for chips in CHIP_COUNTS {
            let got = sharded_mamba_scan(&a, &b, chips);
            let d = max_abs_diff(&got, &want);
            assert!(d < 1e-9, "n={n} chips={chips}: diff={d}");
        }
    }
}

#[test]
fn sharded_scan_reduces_to_prefix_sum_vs_c_scan() {
    // a ≡ 1 turns the recurrence into an inclusive prefix sum — the
    // single-chip scan::serial (C-scan) reference in its purest form.
    let b: Vec<f64> = (0..100).map(|i| (i as f64) * 0.25 - 3.0).collect();
    let a = vec![1.0; b.len()];
    let want = c_scan_inclusive(&b);
    for chips in CHIP_COUNTS {
        let got = sharded_mamba_scan(&a, &b, chips);
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-9, "chips={chips}: diff={d}");
    }
}

#[test]
fn sharded_fft_matches_dft_reference() {
    let mut rng = XorShift::new(102);
    for &(l, r) in &[(256usize, 32usize), (512, 16), (1024, 32), (2048, 32)] {
        let x: Vec<C64> = (0..l)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let want = dft(&x);
        for chips in CHIP_COUNTS {
            for variant in [BaileyVariant::Vector, BaileyVariant::Gemm] {
                let got = sharded_bailey_fft(&x, r, chips, variant);
                let d = max_abs_diff_c(&got, &want);
                assert!(d < 1e-7, "L={l} R={r} chips={chips} {variant:?}: diff={d}");
            }
        }
    }
}

#[test]
fn shard_ranges_absorb_non_power_of_two_remainders() {
    // 1003 over 8 chips: 3 chips of 126, 5 of 125, contiguous, complete.
    let rs = shard_ranges(1003, 8);
    assert_eq!(rs.iter().map(|r| r.len()).sum::<usize>(), 1003);
    assert_eq!(rs.iter().filter(|r| r.len() == 126).count(), 3);
    assert_eq!(rs.iter().filter(|r| r.len() == 125).count(), 5);
}

#[test]
fn strong_scaling_reports_both_models_at_every_chip_count() {
    // The acceptance shape: speedup and communication share per chip
    // count, for Hyena and Mamba.
    let link = InterchipLink::rdu_fabric();
    let dc = DecoderConfig::paper(1 << 20);
    for (model, cfg) in [
        (ModelKind::Mamba, RduConfig::hs_scan_mode()),
        (ModelKind::Hyena, RduConfig::fft_mode()),
    ] {
        let pts = strong_scaling(model, &dc, &CHIP_COUNTS, &cfg, &link).unwrap();
        assert_eq!(pts.len(), CHIP_COUNTS.len());
        for (pt, &chips) in pts.iter().zip(&CHIP_COUNTS) {
            assert_eq!(pt.est.chips, chips);
            assert!(pt.speedup.is_finite() && pt.speedup > 0.0, "{model} chips={chips}");
            let share = pt.est.comm_share();
            assert!((0.0..1.0).contains(&share), "{model} chips={chips} share={share}");
            if chips == 1 {
                assert_eq!(pt.est.comm_seconds, 0.0);
                assert!((pt.speedup - 1.0).abs() < 1e-12);
            } else {
                assert!(pt.est.comm_seconds > 0.0, "{model} chips={chips} pays the fabric");
            }
        }
    }
    // Mamba's O(1) carry exchange must deliver real strong scaling.
    let mamba =
        strong_scaling(ModelKind::Mamba, &dc, &CHIP_COUNTS, &RduConfig::hs_scan_mode(), &link)
            .unwrap();
    assert!(mamba.last().unwrap().speedup > 1.5, "8-chip Mamba {}", mamba.last().unwrap().speedup);
}

#[test]
fn serve_continuous_four_chips_end_to_end() {
    // The acceptance criterion's shape: `serve --continuous --chips 4` on
    // the MockExecutor — here driven through the library API the CLI wraps.
    let chips = 4;
    let mamba_shape = StateShape::mamba(2, 4, 8);
    let hyena_shape = StateShape::hyena(2, 8, 8);
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: chips,
            continuous: Some(
                ContinuousConfig::new(2 * mamba_shape.bytes(), mamba_shape, hyena_shape)
                    .with_chips(chips),
            ),
            ..Default::default()
        },
        Box::new(move || Ok(Box::new(MockExecutor::new(1, 8)) as Box<dyn Executor>)),
    )
    .unwrap();
    let sessions = 16;
    let steps = 6;
    let rxs: Vec<_> = (0..sessions)
        .map(|i| {
            let model = if i % 2 == 0 { ModelKind::Mamba } else { ModelKind::Hyena };
            coord.submit_session(model, vec![0.2 * (i as f32 + 1.0); 8], steps).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut got = 0;
        while let Ok(r) = rx.recv() {
            assert_eq!(r.token_index, Some(got), "session {i} streams in order");
            got += 1;
        }
        assert_eq!(got, steps, "session {i} decoded to completion");
    }
    let per_chip = coord.chip_cache_stats().unwrap();
    assert_eq!(per_chip.len(), chips);
    for (chip, cs) in per_chip.iter().enumerate() {
        assert!(cs.hits + cs.misses > 0, "chip {chip} idle: {cs:?}");
    }
    assert_eq!(coord.scheduler_stats().unwrap().retired, sessions as u64);
    assert_eq!(coord.inflight(), 0);
    coord.shutdown();
}
