//! Cross-layer fusion tests (ISSUE 3): fused and unfused pipelines must be
//! **bit-identical** — fusion is a staging/scheduling transform, never an
//! arithmetic one — and the launch-granularity performance model must show
//! a strict fused-over-unfused win end-to-end, single-chip and sharded.

use ssm_rdu::arch::{InterchipLink, PcuGeometry, RduConfig};
use ssm_rdu::dfmodel::{estimate_fused, estimate_unfused};
use ssm_rdu::fft::BaileyVariant;
use ssm_rdu::pcusim::{fused_conv_program, unfused_conv_programs, Pcu};
use ssm_rdu::runtime::ModelKind;
use ssm_rdu::scan::{mamba_scan_serial, scan_gate_fused, silu};
use ssm_rdu::shard::{sharded_estimate_fused, sharded_mamba_scan, sharded_scan_gate_fused};
use ssm_rdu::util::{C64, XorShift};
use ssm_rdu::workloads::{hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant};

fn rand_c(rng: &mut XorShift, n: usize) -> Vec<C64> {
    (0..n).map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0))).collect()
}

/// Hyena's core: the fused FFT→filter→iFFT conv pipeline vs the same three
/// stages as separate launches, at L ∈ {1K, 4K} transform points — every
/// output must be bit-identical, and both must match the FFT reference.
#[test]
fn hyena_fused_conv_bit_identical_at_1k_and_4k() {
    let mut rng = XorShift::new(301);
    for lanes in [1usize << 10, 1 << 12] {
        let levels = 2 * lanes.trailing_zeros() as usize + 1;
        let pcu = Pcu::fft_mode(PcuGeometry::new(lanes, levels));
        let h = rand_c(&mut rng, lanes);
        let fused = fused_conv_program(lanes, &h);
        assert_eq!(fused.levels.len(), levels);
        assert!(pcu.mappable(&fused).is_ok(), "L={lanes}: {:?}", pcu.mappable(&fused));
        let [p1, p2, p3] = unfused_conv_programs(lanes, &h);

        let x = rand_c(&mut rng, lanes);
        let staged = pcu.eval(&p3, &pcu.eval(&p2, &pcu.eval(&p1, &x)));
        let direct = pcu.eval(&fused, &x);
        assert_eq!(staged, direct, "L={lanes}: fused conv must be bit-identical to unfused");

        // Sanity: both equal the circular-convolution reference.
        let fx = ssm_rdu::fft::fft(&x);
        let fh = ssm_rdu::fft::fft(&h);
        let prod: Vec<C64> = fx.iter().zip(&fh).map(|(&a, &b)| a * b).collect();
        let want = ssm_rdu::fft::ifft(&prod);
        let d = ssm_rdu::util::complex::max_abs_diff_c(&direct, &want);
        assert!(d < 1e-7, "L={lanes}: |d|={d}");
    }
}

/// Mamba's core at ragged (non-power-of-two) lengths: fused scan→gate vs
/// scan-then-gate, single chip — bit-identical.
#[test]
fn mamba_fused_scan_gate_bit_identical_ragged() {
    let mut rng = XorShift::new(302);
    for n in [1usize, 513, 1000, 1023, 4097] {
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let z: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let staged: Vec<f64> =
            mamba_scan_serial(&a, &b).iter().zip(&z).map(|(&h, &zi)| h * silu(zi)).collect();
        assert_eq!(scan_gate_fused(&a, &b, &z), staged, "n={n}");
    }
}

/// The same invariant under `--chips 2` (and other counts): the sharded
/// scan with the gate fused into its carry-application phase vs the staged
/// sharded scan plus a separate gate pass — bit-identical, ragged lengths
/// included.
#[test]
fn mamba_fused_scan_gate_bit_identical_sharded() {
    let mut rng = XorShift::new(303);
    for n in [7usize, 1000, 1023] {
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let z: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
        for chips in [2usize, 3, 4] {
            let staged: Vec<f64> = sharded_mamba_scan(&a, &b, chips)
                .iter()
                .zip(&z)
                .map(|(&h, &zi)| h * silu(zi))
                .collect();
            assert_eq!(
                sharded_scan_gate_fused(&a, &b, &z, chips),
                staged,
                "n={n} chips={chips}"
            );
        }
    }
}

/// The ISSUE-3 acceptance criterion: at L = 4K the fused mapping models
/// strictly lower latency than the unfused one for both decoders on their
/// extended configs (numerics identity is covered by the tests above — the
/// fused sections run the same kernels in the same order).
#[test]
fn fused_models_strictly_faster_at_4k() {
    let dc = DecoderConfig::paper(1 << 12);
    let cases = [
        ("hyena", hyena_decoder(&dc, BaileyVariant::Vector), RduConfig::fft_mode()),
        ("mamba", mamba_decoder(&dc, ScanVariant::Parallel), RduConfig::hs_scan_mode()),
    ];
    for (name, g, cfg) in cases {
        let f = estimate_fused(&g, &cfg).unwrap();
        let u = estimate_unfused(&g, &cfg).unwrap();
        assert!(
            f.total_seconds < u.total_seconds,
            "{name}: fused {} !< unfused {}",
            f.total_seconds,
            u.total_seconds
        );
    }
}

/// Fusion composes with the multi-chip deployment: strictly faster fused
/// per-chip mappings under `--chips 2`, with an unchanged exchange term.
#[test]
fn fused_models_strictly_faster_sharded_2_chips() {
    let dc = DecoderConfig::paper(1 << 12);
    let link = InterchipLink::rdu_fabric();
    for (model, cfg) in [
        (ModelKind::Hyena, RduConfig::fft_mode()),
        (ModelKind::Mamba, RduConfig::hs_scan_mode()),
    ] {
        let f = sharded_estimate_fused(model, &dc, 2, &cfg, &link, true).unwrap();
        let u = sharded_estimate_fused(model, &dc, 2, &cfg, &link, false).unwrap();
        assert_eq!(f.comm_seconds, u.comm_seconds, "{model}: exchange term must not change");
        assert!(
            f.total_seconds < u.total_seconds,
            "{model}: fused {} !< unfused {}",
            f.total_seconds,
            u.total_seconds
        );
    }
}

/// The serialized fallback story holds for the fused program too: on a
/// baseline PCU the fused conv still computes the identical result, only
/// slower — so fusion never *requires* the extension fabric for
/// correctness.
#[test]
fn fused_conv_serialized_fallback_identical() {
    let mut rng = XorShift::new(304);
    let lanes = 32;
    let h = rand_c(&mut rng, lanes);
    let prog = fused_conv_program(lanes, &h);
    let x = rand_c(&mut rng, lanes);
    let base = Pcu::baseline(PcuGeometry::table1());
    let fftm = Pcu::fft_mode(PcuGeometry::table1());
    let (ob, sb) = base.run(&prog, &[x.clone()]);
    let (of, sf) = fftm.run(&prog, &[x]);
    assert!(!sb.spatial && sf.spatial);
    assert_eq!(ob, of);
}
