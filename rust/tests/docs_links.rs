//! Markdown link checker for the docs suite: every relative link in
//! `README.md` and `docs/*.md` must resolve to a file that exists in the
//! repository, so the workload-author guide and architecture docs cannot
//! rot silently. Runs in plain `cargo test` and as its own CI step.
//!
//! External (`http`/`https`/`mailto`) links and intra-page `#anchors` are
//! skipped — this is an offline repo-consistency check, not a crawler.

use std::fs;
use std::path::{Path, PathBuf};

/// Repository root: the directory holding Cargo.toml.
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// The markdown files the docs suite comprises.
fn doc_files() -> Vec<PathBuf> {
    let root = repo_root();
    let mut files = vec![root.join("README.md")];
    let docs = root.join("docs");
    let entries = fs::read_dir(&docs).unwrap_or_else(|e| panic!("read {docs:?}: {e}"));
    for entry in entries {
        let path = entry.expect("dir entry").path();
        if path.extension().is_some_and(|e| e == "md") {
            files.push(path);
        }
    }
    files.sort();
    files
}

/// Extract `](target)` markdown link targets from one file's text.
/// Fenced code blocks are skipped — command examples like
/// `[--options]` in usage text are not links.
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let mut rest = line;
        while let Some(i) = rest.find("](") {
            let tail = &rest[i + 2..];
            match tail.find(')') {
                Some(j) => {
                    out.push(tail[..j].trim().to_string());
                    rest = &tail[j + 1..];
                }
                None => break,
            }
        }
    }
    out
}

#[test]
fn all_relative_markdown_links_resolve() {
    let mut broken: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for file in doc_files() {
        let text = fs::read_to_string(&file).unwrap_or_else(|e| panic!("read {file:?}: {e}"));
        let base = file.parent().expect("doc files live in a directory");
        for target in link_targets(&text) {
            // Skip external links, bare anchors and templated examples.
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
                || target.starts_with('#')
                || target.is_empty()
            {
                continue;
            }
            // Strip an in-file anchor (`path#section`) before resolving.
            let path_part = target.split('#').next().unwrap_or(&target);
            let resolved = base.join(path_part);
            checked += 1;
            if !resolved.exists() {
                broken.push(format!("{}: `{target}` -> {resolved:?}", file.display()));
            }
        }
    }
    assert!(checked > 0, "the docs suite must contain relative links to check");
    assert!(broken.is_empty(), "broken markdown links:\n  {}", broken.join("\n  "));
}

#[test]
fn docs_suite_files_exist() {
    let root = repo_root();
    for required in ["README.md", "docs/ARCHITECTURE.md", "docs/WORKLOADS.md"] {
        assert!(root.join(required).exists(), "missing {required}");
    }
}

#[test]
fn workloads_guide_walkthrough_commands_use_real_workload_names() {
    // The WORKLOADS.md walkthrough must only reference registered workload
    // names in its `--workload` examples, so the commands run as written.
    let text = fs::read_to_string(repo_root().join("docs/WORKLOADS.md")).expect("WORKLOADS.md");
    let names = ssm_rdu::workloads::registry_names();
    let mut found = 0usize;
    for chunk in text.split("--workload").skip(1) {
        let arg = chunk
            .trim_start()
            .split(|c: char| c.is_whitespace() || c == '`')
            .next()
            .unwrap_or("")
            .to_string();
        for name in arg.split(',') {
            // Placeholder tokens like <name> document the flag itself.
            if name.is_empty() || name.starts_with('<') || name.starts_with('{') {
                continue;
            }
            assert!(
                names.contains(&name),
                "WORKLOADS.md references unregistered workload `{name}` (valid: {names:?})"
            );
            found += 1;
        }
    }
    assert!(found > 0, "the guide must show at least one --workload command");
}

#[test]
fn path_resolution_helper_is_honest() {
    // Guard the checker itself: a link to a file that exists resolves, a
    // fabricated one does not.
    let root = repo_root();
    assert!(root.join("Cargo.toml").exists());
    assert!(!root.join("docs/NO_SUCH_FILE.md").exists());
    assert!(Path::new(&root).is_absolute());
}
