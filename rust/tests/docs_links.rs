//! Markdown link checker for the docs suite: every relative link in
//! `README.md` and `docs/*.md` must resolve to a file that exists in the
//! repository, so the workload-author guide and architecture docs cannot
//! rot silently. Runs in plain `cargo test` and as its own CI step.
//!
//! External (`http`/`https`/`mailto`) links and intra-page `#anchors` are
//! skipped — this is an offline repo-consistency check, not a crawler.

use std::fs;
use std::path::{Path, PathBuf};

/// Repository root: the directory holding Cargo.toml.
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// The markdown files the docs suite comprises.
fn doc_files() -> Vec<PathBuf> {
    let root = repo_root();
    let mut files = vec![root.join("README.md")];
    let docs = root.join("docs");
    let entries = fs::read_dir(&docs).unwrap_or_else(|e| panic!("read {docs:?}: {e}"));
    for entry in entries {
        let path = entry.expect("dir entry").path();
        if path.extension().is_some_and(|e| e == "md") {
            files.push(path);
        }
    }
    files.sort();
    files
}

/// Extract `](target)` markdown link targets from one file's text.
/// Fenced code blocks are skipped — command examples like
/// `[--options]` in usage text are not links.
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let mut rest = line;
        while let Some(i) = rest.find("](") {
            let tail = &rest[i + 2..];
            match tail.find(')') {
                Some(j) => {
                    out.push(tail[..j].trim().to_string());
                    rest = &tail[j + 1..];
                }
                None => break,
            }
        }
    }
    out
}

#[test]
fn all_relative_markdown_links_resolve() {
    let mut broken: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for file in doc_files() {
        let text = fs::read_to_string(&file).unwrap_or_else(|e| panic!("read {file:?}: {e}"));
        let base = file.parent().expect("doc files live in a directory");
        for target in link_targets(&text) {
            // Skip external links, bare anchors and templated examples.
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
                || target.starts_with('#')
                || target.is_empty()
            {
                continue;
            }
            // Strip an in-file anchor (`path#section`) before resolving.
            let path_part = target.split('#').next().unwrap_or(&target);
            let resolved = base.join(path_part);
            checked += 1;
            if !resolved.exists() {
                broken.push(format!("{}: `{target}` -> {resolved:?}", file.display()));
            }
        }
    }
    assert!(checked > 0, "the docs suite must contain relative links to check");
    assert!(broken.is_empty(), "broken markdown links:\n  {}", broken.join("\n  "));
}

#[test]
fn docs_suite_files_exist() {
    let root = repo_root();
    for required in ["README.md", "docs/ARCHITECTURE.md", "docs/WORKLOADS.md", "docs/FLEET.md"] {
        assert!(root.join(required).exists(), "missing {required}");
    }
}

/// Every `.rs` file under `rust/src`, read once.
fn rust_sources() -> Vec<(PathBuf, String)> {
    fn walk(dir: &Path, out: &mut Vec<(PathBuf, String)>) {
        for entry in fs::read_dir(dir).unwrap_or_else(|e| panic!("read {dir:?}: {e}")) {
            let path = entry.expect("dir entry").path();
            if path.is_dir() {
                walk(&path, out);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let text =
                    fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path:?}: {e}"));
                out.push((path, text));
            }
        }
    }
    let mut out = Vec::new();
    walk(&repo_root().join("rust/src"), &mut out);
    assert!(!out.is_empty(), "rust/src must contain sources");
    out
}

/// Backtick-quoted inline code spans outside fenced blocks.
fn inline_code_spans(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let mut rest = line;
        while let Some(i) = rest.find('`') {
            let tail = &rest[i + 1..];
            match tail.find('`') {
                Some(j) => {
                    out.push(tail[..j].to_string());
                    rest = &tail[j + 1..];
                }
                None => break,
            }
        }
    }
    out
}

fn is_ident(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Does any source file declare `name` — as an item (`fn`/`struct`/`enum`/
/// `trait`/`mod`/`const`/`static`/`type`/`union`/`macro_rules!`), an enum
/// variant, or a struct field? Pattern-level, not a parser: good enough to
/// catch renamed or deleted symbols referenced from the docs.
fn crate_declares(name: &str, sources: &[(PathBuf, String)]) -> bool {
    let item_forms: Vec<String> = ["fn", "struct", "enum", "trait", "mod", "const", "static", "type", "union"]
        .iter()
        .map(|kw| format!("{kw} {name}"))
        .chain([format!("macro_rules! {name}")])
        .collect();
    // Variant / field forms: the name at a declaration position.
    let member_forms: Vec<String> =
        [":", ",", "(", " {", " ="].iter().map(|suffix| format!("{name}{suffix}")).collect();
    sources.iter().any(|(_, text)| {
        for form in &item_forms {
            // Item declarations: keyword + name followed by a non-ident char.
            for (pos, _) in text.match_indices(form.as_str()) {
                let after = text[pos + form.len()..].chars().next();
                if !after.is_some_and(|c| c.is_ascii_alphanumeric() || c == '_') {
                    return true;
                }
            }
        }
        for form in &member_forms {
            for (pos, _) in text.match_indices(form.as_str()) {
                let before = text[..pos].chars().next_back();
                if !before.is_some_and(|c| c.is_ascii_alphanumeric() || c == '_') {
                    return true;
                }
            }
        }
        false
    })
}

/// Does `rust/src` contain a module at `segments` (e.g. `["fleet",
/// "loadgen"]` → `rust/src/fleet/loadgen.rs` or `.../loadgen/mod.rs`)?
fn module_path_exists(segments: &[&str]) -> bool {
    let base = repo_root().join("rust/src");
    let dir = segments.iter().fold(base.clone(), |p, s| p.join(s));
    if dir.is_dir() && dir.join("mod.rs").exists() {
        return true;
    }
    if segments.is_empty() {
        return false;
    }
    let parent = segments[..segments.len() - 1].iter().fold(base, |p, s| p.join(s));
    parent.join(format!("{}.rs", segments.last().expect("non-empty"))).exists()
}

#[test]
fn backticked_symbol_references_resolve_to_real_items() {
    // Every backtick-quoted `module::symbol` path in the docs must point at
    // something that exists in rust/src — module segments as files/dirs,
    // the final symbol as a declared item (or `Type::member` with both the
    // type and the member declared). Renaming an item without updating the
    // docs fails here.
    let sources = rust_sources();
    let top_modules: Vec<String> = {
        let lib = fs::read_to_string(repo_root().join("rust/src/lib.rs")).expect("lib.rs");
        lib.lines()
            .filter_map(|l| l.trim().strip_prefix("pub mod "))
            .map(|m| m.trim_end_matches(';').to_string())
            .collect()
    };
    assert!(top_modules.contains(&"fleet".to_string()), "lib.rs declares the fleet module");

    let mut broken: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for file in doc_files() {
        let text = fs::read_to_string(&file).unwrap_or_else(|e| panic!("read {file:?}: {e}"));
        for raw in inline_code_spans(&text) {
            if !raw.contains("::") {
                continue;
            }
            // Strip a call/macro suffix (`()`, `(args)`, `!`) and skip
            // anything that is not a plain `a::b::c` path (generics,
            // expressions, flag examples).
            let span = raw.split('(').next().unwrap_or(&raw).trim_end_matches('!');
            let segments: Vec<&str> = span.split("::").collect();
            if segments.len() < 2 || !segments.iter().all(|s| is_ident(s)) {
                continue;
            }
            let segments: Vec<&str> =
                if segments[0] == "crate" { segments[1..].to_vec() } else { segments };
            if segments.len() < 2 {
                continue;
            }
            let first = segments[0];
            let head_is_type = first.chars().next().is_some_and(|c| c.is_ascii_uppercase());
            if !head_is_type && !top_modules.iter().any(|m| m == first) {
                continue; // `std::`, `anyhow::`, CLI examples — out of scope
            }
            checked += 1;
            if head_is_type {
                // `Type::member`: both halves must be declared in-crate.
                let ok = crate_declares(first, &sources)
                    && segments[1..].iter().all(|s| crate_declares(s, &sources));
                if !ok {
                    broken.push(format!("{}: `{raw}`", file.display()));
                }
                continue;
            }
            // `module::…::tail` — greedily extend the module run while each
            // lowercase prefix exists on disk; whatever remains (a type, fn,
            // or constant) must be declared somewhere in the crate. A pure
            // module path (`fleet::loadgen`) is fine on its own.
            let mut mod_len = 1;
            while mod_len < segments.len()
                && segments[mod_len].chars().next().is_some_and(|c| c.is_ascii_lowercase())
                && module_path_exists(&segments[..mod_len + 1])
            {
                mod_len += 1;
            }
            let mods_ok = module_path_exists(&segments[..mod_len]);
            let tail_ok = segments[mod_len..].iter().all(|s| crate_declares(s, &sources));
            if !(mods_ok && tail_ok) {
                broken.push(format!("{}: `{raw}`", file.display()));
            }
        }
    }
    assert!(
        checked >= 10,
        "the docs suite should reference at least 10 `module::symbol` paths (found {checked})"
    );
    assert!(
        broken.is_empty(),
        "stale `module::symbol` references in docs:\n  {}",
        broken.join("\n  ")
    );
}

#[test]
fn symbol_checker_helpers_are_honest() {
    let sources = rust_sources();
    // Real items in this repo resolve…
    assert!(crate_declares("FleetConfig", &sources));
    assert!(crate_declares("run_fleet", &sources));
    assert!(crate_declares("merge_all", &sources));
    assert!(module_path_exists(&["fleet"]));
    assert!(module_path_exists(&["fleet", "loadgen"]));
    assert!(module_path_exists(&["session", "driver"]));
    // …and fabrications do not.
    assert!(!crate_declares("definitely_not_a_real_symbol_xyz", &sources));
    assert!(!module_path_exists(&["fleet", "no_such_module"]));
    assert!(is_ident("run_fleet") && !is_ident("2fast") && !is_ident(""));
}

#[test]
fn workloads_guide_walkthrough_commands_use_real_workload_names() {
    // The WORKLOADS.md walkthrough must only reference registered workload
    // names in its `--workload` examples, so the commands run as written.
    let text = fs::read_to_string(repo_root().join("docs/WORKLOADS.md")).expect("WORKLOADS.md");
    let names = ssm_rdu::workloads::registry_names();
    let mut found = 0usize;
    for chunk in text.split("--workload").skip(1) {
        let arg = chunk
            .trim_start()
            .split(|c: char| c.is_whitespace() || c == '`')
            .next()
            .unwrap_or("")
            .to_string();
        for name in arg.split(',') {
            // Placeholder tokens like <name> document the flag itself.
            if name.is_empty() || name.starts_with('<') || name.starts_with('{') {
                continue;
            }
            assert!(
                names.contains(&name),
                "WORKLOADS.md references unregistered workload `{name}` (valid: {names:?})"
            );
            found += 1;
        }
    }
    assert!(found > 0, "the guide must show at least one --workload command");
}

#[test]
fn path_resolution_helper_is_honest() {
    // Guard the checker itself: a link to a file that exists resolves, a
    // fabricated one does not.
    let root = repo_root();
    assert!(root.join("Cargo.toml").exists());
    assert!(!root.join("docs/NO_SUCH_FILE.md").exists());
    assert!(Path::new(&root).is_absolute());
}
