//! Fleet-tier integration: live migration transparency, drain/fail-stop
//! conservation, and the trace-driven SLO report end-to-end.
//!
//! The load-bearing claims, each checked against ground truth rather than
//! counters alone:
//!
//! * **Migration transparency** — a session live-migrated mid-decode
//!   produces the bit-identical token trajectory to the same trace run
//!   with no migration (the MockExecutor is deterministic and stateless
//!   beyond the `SsmState` that travels with the session, so any drift
//!   would mean the checkpoint/resume path corrupted or replayed state).
//! * **Conservation** — drains and checkpointed fail-stops lose zero
//!   sessions and zero tokens: every session completes, every token is
//!   delivered exactly once and in order (`run_fleet` hard-errors on an
//!   out-of-order delivery), and the delivered values match the
//!   undisturbed run bit-for-bit.
//! * **End-to-end serving** — a 4-node × 2-chip fleet under Poisson and
//!   bursty arrival traces produces a coherent SLO report: quantiles
//!   ordered, goodput ≤ throughput, per-node attribution summing to the
//!   fleet totals.

use ssm_rdu::fleet::{
    generate, mock_factory, run_fleet, Arrival, FleetConfig, FleetReport, FleetScenario,
    PlacementPolicy, TraceConfig,
};
use ssm_rdu::runtime::ModelKind;
use ssm_rdu::session::SessionId;

/// All-at-once arrivals with long decodes: sessions stay live deep into
/// the run, so mid-run scenario events deterministically hit live sessions.
fn burst_trace(n: usize, decode_steps: usize) -> Vec<Arrival> {
    (1..=n)
        .map(|i| Arrival {
            id: i as SessionId,
            at: 0.0,
            model: if i % 2 == 0 { ModelKind::Hyena } else { ModelKind::Mamba },
            prompt_tokens: 16,
            decode_steps,
            affinity: i as u64 % 4,
        })
        .collect()
}

fn expected_tokens(trace: &[Arrival]) -> u64 {
    trace.iter().map(|a| a.decode_steps as u64).sum()
}

fn run(cfg: &FleetConfig, trace: &[Arrival], scenario: &FleetScenario) -> FleetReport {
    run_fleet(cfg, trace, scenario, &mock_factory()).expect("fleet run")
}

#[test]
fn migrated_session_is_bit_identical_to_unmigrated_run() {
    let mut cfg = FleetConfig::demo(2, 2);
    cfg.record_tokens = true;
    let trace = burst_trace(8, 48);
    let base = run(&cfg, &trace, &FleetScenario::default());
    assert_eq!(base.completed, 8);
    assert_eq!(base.token_log.len(), 8, "every session's trajectory recorded");
    for a in &trace {
        assert_eq!(base.token_log[&a.id].len(), a.decode_steps, "full trajectory");
    }

    // Migrate session 1 mid-decode. Its placement is policy-internal, so
    // script a move to each node — the one naming its current home is a
    // no-op, the other performs the live migration.
    let mid = base.sim_seconds * 0.5;
    let scenario =
        FleetScenario { migrate: vec![(mid, 1, 0), (mid, 1, 1)], ..Default::default() };
    let migrated = run(&cfg, &trace, &scenario);
    assert_eq!(migrated.completed, 8);
    assert_eq!(migrated.migrations.migrations, 1, "exactly one real move");
    assert!(migrated.migrations.bytes_moved > 0, "the state crossed the link");
    assert_eq!(
        migrated.token_log, base.token_log,
        "live migration must not change any token of any session"
    );
    // The transfer is not free: modeled time is accounted.
    assert!(migrated.migrations.transfer_seconds > 0.0);
}

#[test]
fn drain_and_fail_stop_conserve_every_token() {
    let mut cfg = FleetConfig::demo(4, 2);
    cfg.record_tokens = true;
    let trace = burst_trace(24, 40);
    let base = run(&cfg, &trace, &FleetScenario::default());
    assert_eq!(base.completed, 24);
    assert_eq!(base.tokens, expected_tokens(&trace));

    // Drain node 1 early, then fail-stop node 0 mid-run.
    let scenario = FleetScenario {
        drain: vec![(base.sim_seconds * 0.25, 1)],
        fail: vec![(base.sim_seconds * 0.5, 0)],
        ..Default::default()
    };
    let r = run(&cfg, &trace, &scenario);
    assert_eq!(r.completed, 24, "zero lost sessions across drain + fail-stop");
    assert_eq!(r.lost_sessions, 0);
    assert_eq!(r.tokens, expected_tokens(&trace), "zero lost tokens, none duplicated");
    assert!(r.migrations.migrations > 0, "the drain evacuated live sessions");
    assert!(r.migrations.failovers > 0, "the fail-stop recovered live sessions");
    assert!(r.per_node[1].drained && !r.per_node[1].failed);
    assert!(r.per_node[0].failed);
    assert_eq!(
        r.token_log, base.token_log,
        "recovery re-executes aborted steps to the bit-identical tokens"
    );
    // Migrated-out / migrated-in bookkeeping balances fleet-wide.
    let out: u64 = r.per_node.iter().map(|n| n.sched.migrated_out).sum();
    let inn: u64 = r.per_node.iter().map(|n| n.sched.migrated_in).sum();
    // Failover resumes also admit via the migration path; drains export via
    // the scheduler. Every resumed session was admitted somewhere.
    assert!(inn >= out, "every exported session re-admitted (plus failover re-admissions)");
}

#[test]
fn fail_stop_without_checkpointing_only_loses_dead_node_sessions() {
    let mut cfg = FleetConfig::demo(2, 2);
    cfg.checkpointing = false;
    let trace = burst_trace(12, 48);
    let base = run(&cfg, &trace, &FleetScenario::default());
    let scenario =
        FleetScenario { fail: vec![(base.sim_seconds * 0.4, 0)], ..Default::default() };
    let r = run(&cfg, &trace, &scenario);
    assert!(r.lost_sessions > 0, "without checkpoints the dead node's sessions are lost");
    assert_eq!(r.completed + r.lost_sessions, 12);
    assert_eq!(r.migrations.failovers, 0);
    // The survivors' tokens still flowed normally.
    assert!(r.tokens > 0 && r.tokens < expected_tokens(&trace));
}

#[test]
fn four_node_fleet_serves_poisson_and_bursty_traces() {
    let cfg = FleetConfig::demo(4, 2);
    let rate = 1.0 / cfg.step_costs().worst() / 30.0;
    for tc in [TraceConfig::poisson(40, rate, 5), TraceConfig::bursty(40, rate, 5)] {
        let kind = tc.process.name();
        let trace = generate(&tc);
        let mut with_slo = cfg.clone();
        // SLO at twice the worst-case single step: tight enough that some
        // queued tokens miss it under bursts, so the cut is exercised.
        with_slo.slo_us = 2.0 * cfg.step_costs().worst() * 1e6;
        let r = run(&with_slo, &trace, &FleetScenario::default());
        assert_eq!(r.sessions, 40, "{kind}");
        assert_eq!(r.completed, 40, "{kind}");
        assert_eq!(r.tokens, expected_tokens(&trace), "{kind}");
        assert!(r.p50_us > 0.0 && r.p50_us <= r.p99_us && r.p99_us <= r.p999_us, "{kind}");
        assert!(r.max_us >= r.p999_us, "{kind}");
        assert!(r.goodput_tok_s <= r.throughput_tok_s + 1e-9, "{kind}");
        assert!(r.slo_attainment > 0.0 && r.slo_attainment <= 1.0, "{kind}");
        assert_eq!(r.per_node.len(), 4, "{kind}");
        assert_eq!(r.per_node.iter().map(|n| n.tokens).sum::<u64>(), r.tokens, "{kind}");
        assert!(r.per_node.iter().filter(|n| n.tokens > 0).count() >= 2, "{kind}: load spread");
        let table = r.node_table();
        assert!(table.lines().count() == 4 + 2, "{kind}: header + 4 nodes + fleet line");
        assert!(r.summary().contains("SLO"), "{kind}");
    }
}

#[test]
fn locality_affine_policy_co_locates_tenants() {
    let mut cfg = FleetConfig::demo(4, 2);
    cfg.policy = PlacementPolicy::LocalityAffine;
    let rate = 1.0 / cfg.step_costs().worst() / 30.0;
    let trace = generate(&TraceConfig::poisson(32, rate, 9));
    let r = run(&cfg, &trace, &FleetScenario::default());
    assert_eq!(r.completed, 32);
    assert!(r.router.affinity_hits > 0, "affine placements must land on preferred nodes");
    assert_eq!(r.router.affinity_hits + r.router.affinity_spills, r.router.placed);
}
