//! Runtime integration: load real AOT artifacts through the PJRT CPU
//! client, execute, and check numerics/structure — the Rust half of the
//! HLO-text round trip (the Python half is python/tests/test_aot.py).
//!
//! These tests require `make artifacts`; they skip (pass trivially) when
//! the artifacts directory is absent so `cargo test` works in a fresh
//! checkout.

use ssm_rdu::runtime::{Manifest, ModelKind, Runtime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = ssm_rdu::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(dir.join("manifest.json")).unwrap();
    assert!(m.seq_len.is_power_of_two());
    assert!(m.batch >= 1);
    for (kind, meta) in &m.models {
        assert!(dir.join(&meta.path).exists(), "{kind}: {}", meta.path);
        assert_eq!(meta.input_shape, [m.batch, m.seq_len, m.d_model]);
        assert_eq!(meta.input_shape, meta.output_shape);
    }
}

#[test]
fn mamba_artifact_executes_with_finite_output() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &[ModelKind::Mamba]).unwrap();
    let m = rt.model(ModelKind::Mamba).unwrap();
    let n: usize = m.meta.input_shape.iter().product();
    let x: Vec<f32> = (0..n).map(|i| ((i % 17) as f32 - 8.0) / 10.0).collect();
    let y = m.execute(&x).unwrap();
    assert_eq!(y.len(), n);
    assert!(y.iter().all(|v| v.is_finite()));
    // A residual decoder layer is not the identity but stays correlated.
    assert!(y.iter().zip(&x).any(|(a, b)| (a - b).abs() > 1e-6));
}

#[test]
fn execution_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &[ModelKind::Mamba]).unwrap();
    let m = rt.model(ModelKind::Mamba).unwrap();
    let n: usize = m.meta.input_shape.iter().product();
    let x = vec![0.25f32; n];
    let y1 = m.execute(&x).unwrap();
    let y2 = m.execute(&x).unwrap();
    assert_eq!(y1, y2);
}

#[test]
fn batch_slots_are_independent() {
    // Slot i's output depends only on slot i's input (no cross-batch mixing
    // in the decoder layers) — the property the dynamic batcher relies on
    // when padding partial batches.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &[ModelKind::Mamba]).unwrap();
    let m = rt.model(ModelKind::Mamba).unwrap();
    let slots = m.batch_slots();
    if slots < 2 {
        return;
    }
    let per = m.elems_per_slot();
    let n = slots * per;
    let mut x1 = vec![0.1f32; n];
    let mut x2 = vec![0.1f32; n];
    // Same slot-0 payload, different slot-1 payload.
    for v in x2[per..2 * per].iter_mut() {
        *v = -0.7;
    }
    x1[0] = 0.1;
    let y1 = m.execute(&x1).unwrap();
    let y2 = m.execute(&x2).unwrap();
    let slot0_diff = y1[..per]
        .iter()
        .zip(&y2[..per])
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(slot0_diff < 1e-5, "slot 0 must not see slot 1: diff={slot0_diff}");
}

#[test]
fn wrong_input_size_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &[ModelKind::Mamba]).unwrap();
    let m = rt.model(ModelKind::Mamba).unwrap();
    assert!(m.execute(&[1.0, 2.0, 3.0]).is_err());
}

#[test]
fn load_subset_excludes_others() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &[ModelKind::Mamba]).unwrap();
    assert!(rt.model(ModelKind::Mamba).is_ok());
    assert!(rt.model(ModelKind::Hyena).is_err());
}
