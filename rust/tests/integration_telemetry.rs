//! Trace-output invariants for the telemetry recorder (ISSUE 6 satellite):
//! spans are well-nested per thread, timestamps are monotone per track, the
//! recorded span *set* is deterministic across thread interleavings, and
//! the emitted Chrome trace JSON round-trips through `util::json`.
//!
//! The recorder is process-global, so every test here serializes on one
//! lock and drains the sink at entry — same discipline as the unit tests.

use ssm_rdu::fft::{fft_conv_linear, BaileyVariant};
use ssm_rdu::runtime::WorkerPool;
use ssm_rdu::shard::{sharded_bailey_fft_pooled, sharded_mamba_scan_pooled};
use ssm_rdu::telemetry::{
    self, chip_track, counter, drain, trace_json, EventKind, TraceEvent,
};
use ssm_rdu::util::json::Json;
use ssm_rdu::util::{C64, XorShift};
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::{Mutex, MutexGuard, OnceLock};

fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Run the instrumented sharded hot paths (Mamba carry-exchange scan +
/// Bailey FFT transpose) over a `threads`-wide pool with tracing on, and
/// return everything recorded. Pool workers are scoped threads, so their
/// buffers flush before each call returns.
fn record_pooled_run(threads: usize) -> Vec<TraceEvent> {
    drain();
    telemetry::enable();
    let pool = WorkerPool::new(threads);
    let mut rng = XorShift::new(99);
    let n = 4096;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let _ = sharded_mamba_scan_pooled(&a, &b, 4, &pool);
    let x: Vec<C64> = (0..2048)
        .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
        .collect();
    let _ = sharded_bailey_fft_pooled(&x, 32, 4, BaileyVariant::Vector, &pool);
    telemetry::disable();
    drain()
}

#[test]
fn span_end_times_are_monotone_per_track() {
    let _g = lock();
    let evs = record_pooled_run(3);
    assert!(!evs.is_empty());
    // A thread appends to its buffer in completion order and buffers flush
    // to the sink in order, so each own-thread track's end times must be
    // non-decreasing in drained order. (Chip tracks are excluded: several
    // threads may post instants to the same chip concurrently.)
    let mut last_end: BTreeMap<u64, u64> = BTreeMap::new();
    for e in evs.iter().filter(|e| e.tid < chip_track(0)) {
        let end = e.ts_ns + e.dur_ns;
        if let Some(&prev) = last_end.get(&e.tid) {
            assert!(
                end >= prev,
                "track {} went backwards: {} after {} ({})",
                e.tid,
                end,
                prev,
                e.name
            );
        }
        last_end.insert(e.tid, end);
    }
}

#[test]
fn spans_are_well_nested_per_track() {
    let _g = lock();
    let evs = record_pooled_run(3);
    let mut by_tid: BTreeMap<u64, Vec<&TraceEvent>> = BTreeMap::new();
    for e in evs.iter().filter(|e| e.kind == EventKind::Span) {
        by_tid.entry(e.tid).or_default().push(e);
    }
    assert!(!by_tid.is_empty());
    for (tid, mut spans) in by_tid {
        // Earliest first; at equal start the longer span is the parent.
        spans.sort_by(|x, y| x.ts_ns.cmp(&y.ts_ns).then(y.dur_ns.cmp(&x.dur_ns)));
        let mut stack: Vec<(u64, u64)> = Vec::new(); // (start, end)
        for s in spans {
            let (start, end) = (s.ts_ns, s.ts_ns + s.dur_ns);
            while let Some(&(_, top_end)) = stack.last() {
                if start >= top_end {
                    stack.pop(); // sibling: the previous span closed first
                } else {
                    assert!(
                        end <= top_end,
                        "track {tid}: span `{}` [{start},{end}) straddles its parent's \
                         end {top_end} — not well nested",
                        s.name
                    );
                    break;
                }
            }
            stack.push((start, end));
        }
    }
}

#[test]
fn span_set_is_deterministic_across_interleavings() {
    let _g = lock();
    let count = |evs: &[TraceEvent]| -> BTreeMap<String, usize> {
        let mut m = BTreeMap::new();
        for e in evs {
            *m.entry(e.name.to_string()).or_insert(0) += 1;
        }
        m
    };
    let first = count(&record_pooled_run(3));
    let second = count(&record_pooled_run(3));
    assert_eq!(first, second, "same work must record the same span multiset");
    // The phases the ISSUE names must all be visible.
    for name in ["scan.local", "scan.carry_exchange", "scan.carry_in", "scan.apply",
                 "fft.columns", "fft.transpose", "fft.rows", "pool.map"] {
        assert!(first.contains_key(name), "missing expected span/instant `{name}`");
    }
    // Per-chip attribution: 4 chips get one carry-in marker each.
    assert_eq!(first["scan.carry_in"], 4);
}

#[test]
fn trace_json_round_trips_and_writes_to_disk() {
    let _g = lock();
    let evs = record_pooled_run(2);
    let json = trace_json(&evs);
    let doc = Json::parse(&json).expect("emitted trace must be valid JSON");
    let te = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let spans = te
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .count();
    let instants = te
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
        .count();
    assert_eq!(spans, evs.iter().filter(|e| e.kind == EventKind::Span).count());
    assert_eq!(instants, evs.iter().filter(|e| e.kind == EventKind::Instant).count());
    // Chip tracks carry the carry-exchange markers on the host process.
    let chip0 = chip_track(0) as f64;
    assert!(
        te.iter().any(|e| e.get("tid").and_then(Json::as_f64) == Some(chip0)),
        "chip 0 track must appear in the export"
    );
    // And the file path works end to end.
    let path = std::env::temp_dir().join(format!("ssm_rdu_trace_{}.json", std::process::id()));
    telemetry::write_trace(&path, &evs).expect("write trace file");
    let read_back = std::fs::read_to_string(&path).expect("read trace file");
    Json::parse(&read_back).expect("trace file must parse");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn disabled_runs_record_nothing() {
    let _g = lock();
    drain();
    assert!(!telemetry::enabled());
    let pool = WorkerPool::new(3);
    let a = vec![0.5; 1024];
    let b = vec![0.25; 1024];
    let _ = sharded_mamba_scan_pooled(&a, &b, 4, &pool);
    assert!(drain().is_empty(), "disabled tracing must record zero events");
}

#[test]
fn plan_cache_counters_track_hits_and_misses() {
    let _g = lock();
    let hits = counter("fft.plan_cache.hits");
    let misses = counter("fft.plan_cache.misses");
    let (h0, m0) = (hits.load(Ordering::Relaxed), misses.load(Ordering::Relaxed));
    let u = vec![1.0f64; 300];
    let k = vec![0.5f64; 300];
    // The conv plan cache is thread-local and this test owns its thread:
    // the first conv at this size is a miss, the second a hit.
    let _ = fft_conv_linear(&u, &k);
    assert!(misses.load(Ordering::Relaxed) > m0, "first conv must miss the plan cache");
    let after_first = hits.load(Ordering::Relaxed);
    let _ = fft_conv_linear(&u, &k);
    assert!(
        hits.load(Ordering::Relaxed) > after_first.max(h0),
        "repeat conv must hit the plan cache"
    );
}
