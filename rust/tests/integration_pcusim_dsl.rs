//! Differential wall for the `define_pcu_program!` migration.
//!
//! Every DSL-authored pcusim program must be **bit-identical** to its
//! hand-assembled `legacy_*` oracle — same level tables at construction,
//! same outputs (down to the f64 bit pattern) and same `ExecStats` when
//! executed on both the extension and the baseline fabric, across
//! power-of-two and non-power-of-two batch lengths. On top of that, the
//! single-step debugger must agree with the batch engine under
//! breakpoints, deterministic resume, and snapshot JSON round-trips.

use ssm_rdu::arch::PcuGeometry;
use ssm_rdu::pcusim::{
    self, legacy, stage_timeline, timeline_cycles, DebugSession, Pcu, Program, RunOutcome,
};
use ssm_rdu::util::json::Json;
use ssm_rdu::util::{C64, XorShift};

fn rand_c(rng: &mut XorShift, n: usize) -> Vec<C64> {
    (0..n).map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0))).collect()
}

fn rand_batch(rng: &mut XorShift, vectors: usize, lanes: usize) -> Vec<Vec<C64>> {
    (0..vectors).map(|_| rand_c(rng, lanes)).collect()
}

/// Every (DSL, legacy) constructor pair at a given lane count, sharing the
/// same randomly drawn filter taps / twiddle factors.
fn pairs(lanes: usize, rng: &mut XorShift) -> Vec<(Program, Program)> {
    let h = rand_c(rng, lanes);
    let tw = rand_c(rng, lanes);
    let mut out = vec![
        (pcusim::fft_program(lanes), legacy::legacy_fft_program(lanes)),
        (pcusim::idit_fft_program(lanes), legacy::legacy_idit_fft_program(lanes)),
        (pcusim::dif_fft_program(lanes), legacy::legacy_dif_fft_program(lanes)),
        (pcusim::freq_filter_program(&h), legacy::legacy_freq_filter_program(&h)),
        (pcusim::fused_conv_program(lanes, &h), legacy::legacy_fused_conv_program(lanes, &h)),
        (pcusim::hs_scan_program(lanes), legacy::legacy_hs_scan_program(lanes)),
        (pcusim::b_scan_program(lanes), legacy::legacy_b_scan_program(lanes)),
        (pcusim::reduction_program(lanes), legacy::legacy_reduction_program(lanes)),
        (pcusim::twiddle_program(&tw), legacy::legacy_twiddle_program(&tw)),
    ];
    let [d1, d2, d3] = pcusim::unfused_conv_programs(lanes, &h);
    let [l1, l2, l3] = legacy::legacy_unfused_conv_programs(lanes, &h);
    out.push((d1, l1));
    out.push((d2, l2));
    out.push((d3, l3));
    out
}

/// Exact f64 bit patterns of a batch of output vectors: "bit-identical"
/// means exactly this, not approximate equality.
fn bits(out: &[Vec<C64>]) -> Vec<(u64, u64)> {
    out.iter().flatten().map(|z| (z.re.to_bits(), z.im.to_bits())).collect()
}

// ---------------------------------------------------------------- structure

#[test]
fn dsl_programs_are_structurally_identical_to_legacy() {
    let mut rng = XorShift::new(0x15541);
    for lanes in [2usize, 4, 8, 32] {
        for (dsl, leg) in pairs(lanes, &mut rng) {
            assert_eq!(dsl.name, leg.name, "at {lanes} lanes");
            assert_eq!(dsl.mode, leg.mode, "{}", dsl.name);
            assert_eq!(
                dsl.levels, leg.levels,
                "{} at {lanes} lanes: DSL and legacy level tables must be bit-identical",
                dsl.name
            );
            assert_eq!(
                dsl.labels.len(),
                dsl.levels.len(),
                "{}: the DSL labels every stage",
                dsl.name
            );
            assert!(leg.labels.is_empty(), "{}: legacy oracles stay unlabeled", leg.name);
        }
    }
}

#[test]
fn non_pow2_elementwise_widths_build_and_match_legacy() {
    // The execution engine's geometry is power-of-two-laned, so odd widths
    // exercise the construction path only: the level table is the contract.
    let mut rng = XorShift::new(0x0dd);
    for width in [3usize, 5, 7] {
        let factors = rand_c(&mut rng, width);
        let dsl = pcusim::twiddle_program(&factors);
        let leg = legacy::legacy_twiddle_program(&factors);
        assert_eq!(dsl.width(), width);
        assert_eq!(dsl.levels, leg.levels, "twiddle at width {width}");
    }
}

// ---------------------------------------------------------------- behavior

#[test]
fn dsl_programs_run_bit_identically_to_legacy_on_both_fabrics() {
    let mut rng = XorShift::new(0xd1ff);
    for lanes in [2usize, 4, 8] {
        let progs = pairs(lanes, &mut rng);
        let geom = PcuGeometry::new(lanes, 12);
        for vectors in [1usize, 3, 4, 7, 8, 17] {
            let inputs = rand_batch(&mut rng, vectors, lanes);
            for (dsl, leg) in &progs {
                // Extension fabric (spatial where the mode allows it) and
                // baseline fabric (scan/FFT programs serialize).
                for pcu in [Pcu::with_extension(geom, dsl.mode), Pcu::baseline(geom)] {
                    let (a, sa) = pcu.run(dsl, &inputs);
                    let (b, sb) = pcu.run(leg, &inputs);
                    assert_eq!(
                        bits(&a),
                        bits(&b),
                        "{} lanes={lanes} vectors={vectors}: outputs must be bit-identical",
                        dsl.name
                    );
                    assert_eq!(sa, sb, "{}: ExecStats (incl. cycles) must match", dsl.name);
                }
            }
        }
    }
}

#[test]
fn timeline_totals_pin_to_exec_stats_for_macro_programs() {
    let mut rng = XorShift::new(0x7177);
    let lanes = 8usize;
    let geom = PcuGeometry::new(lanes, 12);
    let h = rand_c(&mut rng, lanes);
    let vectors = 6usize;
    let inputs = rand_batch(&mut rng, vectors, lanes);
    let progs = [
        pcusim::fused_conv_program(lanes, &h),
        pcusim::fft_program(lanes),
        pcusim::hs_scan_program(lanes),
        pcusim::b_scan_program(lanes),
    ];
    for prog in &progs {
        // Spatial on the matching extension fabric: timeline total == cycles.
        let ext = Pcu::with_extension(geom, prog.mode);
        let (_, stats) = ext.run(prog, &inputs);
        assert!(stats.spatial, "{}", prog.name);
        let evs = stage_timeline(&ext, prog, vectors, 0);
        assert_eq!(timeline_cycles(&evs), stats.cycles, "{}: spatial timeline", prog.name);
        // Serialized on baseline: the export covers the stage-0 work cycles;
        // the engine additionally accounts the (stages-1)*levels drain.
        let base = Pcu::baseline(geom);
        let (_, sstats) = base.run(prog, &inputs);
        assert!(!sstats.spatial, "{}", prog.name);
        let sevs = stage_timeline(&base, prog, vectors, 0);
        let drain = (geom.stages as u64 - 1) * prog.levels.len() as u64;
        assert_eq!(
            timeline_cycles(&sevs),
            sstats.cycles - drain,
            "{}: serialized timeline",
            prog.name
        );
    }
}

// ---------------------------------------------------------------- debugger

#[test]
fn stage_and_cycle_breakpoints_are_deterministic() {
    let lanes = 32usize;
    let mut rng = XorShift::new(0xb0b);
    let h = rand_c(&mut rng, lanes);
    let prog = pcusim::fused_conv_program(lanes, &h);
    let inputs = rand_batch(&mut rng, 5, lanes);
    let pcu = Pcu::with_extension(PcuGeometry::new(lanes, 12), prog.mode);
    let hits = |prog: &Program, inputs: &[Vec<C64>]| -> Vec<(u64, Option<usize>)> {
        let mut dbg = DebugSession::new(pcu, prog, inputs.to_vec());
        dbg.break_on_label("filter").expect("fused conv has a filter stage");
        let mut seen = Vec::new();
        loop {
            match dbg.run() {
                RunOutcome::Break(hit) => seen.push((hit.cycle, hit.vector)),
                RunOutcome::Done => return seen,
                other => panic!("unexpected {other:?}"),
            }
        }
    };
    let first = hits(&prog, &inputs);
    let second = hits(&prog, &inputs);
    assert_eq!(first, second, "same program + inputs must break at the same cycles");
    // filter is level log2(32) = 5; vector v reaches it at cycle 6 + v.
    assert_eq!(first, (0..5).map(|v| (6 + v as u64, Some(v))).collect::<Vec<_>>());
}

#[test]
fn resume_after_break_matches_uninterrupted_engine_run() {
    let lanes = 8usize;
    let mut rng = XorShift::new(0x5e5);
    let h = rand_c(&mut rng, lanes);
    let prog = pcusim::fused_conv_program(lanes, &h);
    let inputs = rand_batch(&mut rng, 7, lanes);
    let geom = PcuGeometry::new(lanes, 12);
    for (pcu, regime) in [
        (Pcu::with_extension(geom, prog.mode), "spatial"),
        (Pcu::baseline(geom), "serialized"),
    ] {
        let mut dbg = DebugSession::new(pcu, &prog, inputs.clone());
        dbg.break_on_cycle(3);
        dbg.break_on_stage(1);
        let mut breaks = 0usize;
        loop {
            match dbg.run() {
                RunOutcome::Break(_) => breaks += 1,
                RunOutcome::Done => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(breaks > 1, "{regime}: expected multiple breakpoint hits");
        let (want_out, want_stats) = pcu.run(&prog, &inputs);
        assert_eq!(bits(dbg.outputs()), bits(&want_out), "{regime}: outputs after resume");
        assert_eq!(dbg.stats().unwrap(), want_stats, "{regime}: stats after resume");
    }
}

#[test]
fn snapshot_round_trips_through_util_json() {
    let lanes = 32usize;
    let mut rng = XorShift::new(0x5a9);
    let h = rand_c(&mut rng, lanes);
    let prog = pcusim::fused_conv_program(lanes, &h);
    let inputs = rand_batch(&mut rng, 8, lanes);
    let pcu = Pcu::with_extension(PcuGeometry::new(lanes, 12), prog.mode);
    let mut dbg = DebugSession::new(pcu, &prog, inputs);
    // The CI smoke contract: breaking on the filter stage of fused_conv
    // must observe in-flight NoC traffic from the dif stages behind it.
    dbg.break_on_label("filter").unwrap();
    match dbg.run() {
        RunOutcome::Break(hit) => assert_eq!(hit.stage, Some(5)),
        other => panic!("expected break, got {other:?}"),
    }
    let snap = dbg.snapshot();
    assert!(!snap.noc.is_empty(), "dif stages must show cross-lane traffic");
    assert!(!snap.stages.is_empty());
    let doc = snap.to_json();
    let parsed = Json::parse(&doc).unwrap_or_else(|e| panic!("snapshot emitted invalid JSON: {e}"));
    let back = pcusim::Snapshot::from_json(&parsed).expect("snapshot JSON failed to parse back");
    assert_eq!(back, snap, "snapshot must survive the JSON round-trip exactly");
}

#[test]
fn run_to_then_finish_equals_engine() {
    let lanes = 4usize;
    let mut rng = XorShift::new(0xee7);
    let prog = pcusim::b_scan_program(lanes);
    let inputs = rand_batch(&mut rng, 9, lanes);
    let pcu = Pcu::with_extension(PcuGeometry::new(lanes, 12), prog.mode);
    let mut dbg = DebugSession::new(pcu, &prog, inputs.clone());
    assert_eq!(dbg.run_to(2), RunOutcome::AtCycle(2));
    assert_eq!(dbg.cycle(), 2);
    assert_eq!(dbg.run(), RunOutcome::Done);
    let (want_out, want_stats) = pcu.run(&prog, &inputs);
    assert_eq!(bits(dbg.outputs()), bits(&want_out));
    assert_eq!(dbg.stats().unwrap(), want_stats);
}
