//! Cross-module integration: workload builders → DFModel mapping →
//! estimates → platform models, plus the cross-layer algorithm agreement
//! (PCU simulator vs the algorithm substrates) promised in DESIGN.md §7.

use ssm_rdu::arch::{GpuSpec, PcuGeometry, RduConfig, VgaSpec};
use ssm_rdu::dfmodel;
use ssm_rdu::fft::{self, BaileyVariant};
use ssm_rdu::gpu;
use ssm_rdu::pcusim::{self, Pcu};
use ssm_rdu::scan;
use ssm_rdu::util::complex::max_abs_diff_c;
use ssm_rdu::util::{max_abs_diff, C64, XorShift};
use ssm_rdu::vga;
use ssm_rdu::workloads::{
    attention_decoder, hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant,
};

/// Every decoder × every RDU config maps and estimates without error.
#[test]
fn all_workloads_map_on_all_configs() {
    let configs = [
        RduConfig::baseline(),
        RduConfig::fft_mode(),
        RduConfig::hs_scan_mode(),
        RduConfig::b_scan_mode(),
    ];
    let dc = DecoderConfig::paper(1 << 18);
    let graphs = vec![
        attention_decoder(&dc),
        hyena_decoder(&dc, BaileyVariant::Vector),
        hyena_decoder(&dc, BaileyVariant::Gemm),
        mamba_decoder(&dc, ScanVariant::CScan),
        mamba_decoder(&dc, ScanVariant::Parallel),
    ];
    for cfg in &configs {
        for g in &graphs {
            let est = dfmodel::estimate(g, cfg).expect("mappable");
            assert!(est.total_seconds.is_finite() && est.total_seconds > 0.0, "{} on {}", g.name, cfg);
            assert!(est.total_seconds >= est.memory_seconds);
        }
    }
}

/// The interconnect extension only ever *helps* (monotonicity invariant).
#[test]
fn extensions_never_hurt() {
    let dc = DecoderConfig::paper(1 << 18);
    let hy = hyena_decoder(&dc, BaileyVariant::Vector);
    let ma = mamba_decoder(&dc, ScanVariant::Parallel);
    let base = RduConfig::baseline();
    assert!(
        dfmodel::estimate(&hy, &RduConfig::fft_mode()).unwrap().total_seconds
            <= dfmodel::estimate(&hy, &base).unwrap().total_seconds
    );
    assert!(
        dfmodel::estimate(&ma, &RduConfig::hs_scan_mode()).unwrap().total_seconds
            <= dfmodel::estimate(&ma, &base).unwrap().total_seconds
    );
    // ...and is irrelevant to workloads that don't use it.
    let at = attention_decoder(&dc);
    let a_base = dfmodel::estimate(&at, &base).unwrap().total_seconds;
    let a_fft = dfmodel::estimate(&at, &RduConfig::fft_mode()).unwrap().total_seconds;
    assert!((a_base - a_fft).abs() / a_base < 1e-9);
}

/// Dataflow execution (RDU) beats kernel-by-kernel (GPU) per unit compute:
/// the RDU at the same nameplate FLOPs would still win on memory traffic.
#[test]
fn dataflow_beats_kernel_by_kernel_on_memory_traffic() {
    let dc = DecoderConfig::paper(1 << 20);
    let g = hyena_decoder(&dc, BaileyVariant::Vector);
    let rdu = dfmodel::estimate(&g, &RduConfig::fft_mode()).unwrap();
    let gpu_est = gpu::estimate(&g, &GpuSpec::a100());
    // GPU stages every intermediate through DRAM; RDU only the graph I/O.
    assert!(gpu_est.memory_seconds > rdu.memory_seconds * 5.0);
}

/// VGA runs Hyena but rejects Mamba (fixed-function), RDU runs both —
/// the paper's generality argument.
#[test]
fn vga_generality_gap() {
    let dc = DecoderConfig::paper(1 << 18);
    let spec = VgaSpec::table2();
    assert!(vga::estimate(&hyena_decoder(&dc, BaileyVariant::Vector), &spec).is_ok());
    assert!(vga::estimate(&mamba_decoder(&dc, ScanVariant::Parallel), &spec).is_err());
    assert!(dfmodel::estimate(&mamba_decoder(&dc, ScanVariant::Parallel), &RduConfig::b_scan_mode()).is_ok());
}

/// Cross-layer loop 1: the PCU FFT program (cycle-level simulator) agrees
/// with the Bailey substrate's tiles and the Cooley–Tukey oracle.
#[test]
fn pcusim_fft_agrees_with_substrates() {
    let mut rng = XorShift::new(77);
    let pcu = Pcu::fft_mode(PcuGeometry::table1());
    let prog = pcusim::fft_program(32);
    for _ in 0..50 {
        let x: Vec<C64> = (0..32)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let via_pcu = pcu.eval(&prog, &pcusim::bit_reverse(&x));
        let via_ct = fft::fft(&x);
        let via_bailey = fft::bailey_fft(&x, 32, BaileyVariant::Vector);
        assert!(max_abs_diff_c(&via_pcu, &via_ct) < 1e-10);
        assert!(max_abs_diff_c(&via_pcu, &via_bailey) < 1e-10);
    }
}

/// Cross-layer loop 2: the PCU scan programs agree with the scan
/// substrates on random tiles.
#[test]
fn pcusim_scans_agree_with_substrates() {
    let mut rng = XorShift::new(78);
    let hs_pcu = Pcu::hs_scan_mode(PcuGeometry::table1());
    let b_pcu = Pcu::b_scan_mode(PcuGeometry::table1());
    let hs_prog = pcusim::hs_scan_program(32);
    let b_prog = pcusim::b_scan_program(32);
    for _ in 0..50 {
        let xs = rng.vec(32, -2.0, 2.0);
        let x: Vec<C64> = xs.iter().map(|&v| C64::real(v)).collect();
        let hs: Vec<f64> = hs_pcu.eval(&hs_prog, &x).iter().map(|z| z.re).collect();
        let b: Vec<f64> = b_pcu.eval(&b_prog, &x).iter().map(|z| z.re).collect();
        assert!(max_abs_diff(&hs, &scan::hillis_steele_inclusive(&xs)) < 1e-12);
        assert!(max_abs_diff(&b, &scan::blelloch_exclusive(&xs)) < 1e-12);
        // HS (inclusive) minus input = B (exclusive).
        let derived: Vec<f64> = hs.iter().zip(&xs).map(|(h, v)| h - v).collect();
        assert!(max_abs_diff(&derived, &b) < 1e-10);
    }
}

/// The tiled scan (multi-PCU decomposition) matches the flat algorithms at
/// paper-scale lengths.
#[test]
fn tiled_scan_composes_at_scale() {
    let mut rng = XorShift::new(79);
    let xs = rng.vec(1 << 15, -1.0, 1.0);
    let flat = scan::c_scan_exclusive(&xs);
    let tiled = scan::tiled_exclusive(&xs, 32);
    assert!(max_abs_diff(&flat, &tiled) < 1e-7);
}

/// Mamba's recurrence: the parallel (lifted) form matches the serial form —
/// the algorithmic fact the scan-mode hardware exploits.
#[test]
fn mamba_recurrence_lift_exact() {
    let mut rng = XorShift::new(80);
    let n = 1 << 12;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 0.999)).collect();
    let b: Vec<f64> = rng.vec(n, -1.0, 1.0);
    let serial = scan::mamba_scan_serial(&a, &b);
    let parallel = scan::mamba_scan_parallel(&a, &b);
    assert!(max_abs_diff(&serial, &parallel) < 1e-9);
}

/// Sectioning invariant: when a graph is forced to section (tiny SRAM),
/// the estimate still covers all kernels and only gets slower.
#[test]
fn sectioning_preserves_totals() {
    let dc = DecoderConfig::paper(1 << 18);
    let g = hyena_decoder(&dc, BaileyVariant::Vector);
    let normal = RduConfig::fft_mode();
    let mut tiny = RduConfig::fft_mode();
    // Shrink SRAM enough to force multi-sectioning while every single
    // kernel (largest corner-turn buffer = one 64 MB iFFT input) still fits.
    tiny.spec.pmu_bytes /= 8;
    let e1 = dfmodel::estimate(&g, &normal).unwrap();
    let e2 = dfmodel::estimate(&g, &tiny).unwrap();
    assert!(e2.sections > e1.sections, "{} vs {}", e2.sections, e1.sections);
    assert_eq!(e1.kernels.len(), e2.kernels.len());
    // Sectioning is compute-neutral under balanced allocation (the same
    // total PCU-seconds spread over more phases, modulo integer rounding)
    // but strictly adds DRAM boundary staging.
    assert!(e2.total_seconds >= e1.total_seconds * 0.9);
    assert!(e2.memory_seconds > e1.memory_seconds, "boundary staging must cost DRAM traffic");
}
